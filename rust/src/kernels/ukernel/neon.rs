//! NEON micro-kernels (aarch64): the paper's VAND + VCNT + accumulate
//! bitserial inner loop on 128-bit q-registers.
//!
//! Structure mirrors the AVX2 entry: AND two packed plane chunks, per-byte
//! popcount with `vcntq_u8` (each byte ≤ 8, so 31 chunks stay < 256 before
//! the `vaddlvq_u8` horizontal flush), weight planes chunk-padded by the
//! `TileN` prepack so every weight load is a whole in-bounds vector, and a
//! zero-padded stack chunk for the activation tail. The int8 path takes a
//! `+dotprod`-gated SDOT kernel when the CPU reports the feature (u8·i8
//! via the unsigned-dot + XOR-0x80 offset identity below) and falls back
//! to the portable scalar GEMM otherwise.

use std::arch::aarch64::*;

use super::{Isa, PackedW, UKernel, UKernelDesc};
use crate::dlrt::graph::qp_qn;
use crate::dlrt::tensor::Packed;
use crate::kernels::bitserial::{row_code_sum, MAX_BITS};
use crate::util::threads;

/// `u64` words per 128-bit chunk.
const CHUNK: usize = 2;
/// Chunks between byte-accumulator flushes (per-byte counts ≤ 8·31 < 256).
const FLUSH: usize = 31;
/// M (activation-row) tile.
const TILE_M: usize = 32;
/// N (output-channel) tile.
const TILE_N: usize = 16;

pub static KERNEL: UKernel = UKernel {
    desc: UKernelDesc { isa: Isa::Neon, tile_m: TILE_M, tile_n: TILE_N, k_unroll: CHUNK },
    gemm_bit,
    gemm_u8i8,
    gemm_f32: crate::kernels::fp32::gemm_rowmajor_bt,
};

fn gemm_bit(
    desc: &UKernelDesc,
    a: &Packed,
    w: &PackedW,
    w_bits_signed: usize,
    out: &mut [i32],
    nthreads: usize,
) {
    assert_eq!(a.k, w.k, "reduction dim mismatch");
    assert_eq!(a.words_per_row, w.words_per_row);
    assert_eq!(w.plane_stride % CHUNK, 0, "NEON kernel needs chunk-padded weight planes");
    assert!(a.bits <= MAX_BITS && w.bits <= MAX_BITS);
    let (m, n) = (a.rows, w.rows);
    assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    let (_, qn) = qp_qn(w_bits_signed as u8, true);
    // tuned geometry: M clamps to the stack-staged block (corrections +
    // activation tail chunks are const-sized), N is free loop blocking
    let tile_m = desc.tile_m.clamp(1, TILE_M);
    let tile_n = desc.tile_n.max(1);
    threads::par_chunks_rows(out, n, nthreads, |row0, chunk| {
        // SAFETY: this entry is only reachable through the registry, which
        // hands out the NEON kernel after runtime feature detection
        // (`host_supports`), satisfying `bit_rows_block`'s target_feature
        // contract.
        unsafe { bit_rows_block(a, w, qn, row0, chunk, n, tile_m, tile_n) }
    });
}

/// One worker's block of whole output rows, tiled `tile_m`×`tile_n` like the
/// scalar kernel (exact integer arithmetic — tiling cannot change results).
#[target_feature(enable = "neon")]
#[allow(clippy::too_many_arguments)]
unsafe fn bit_rows_block(
    a: &Packed,
    w: &PackedW,
    qn: i32,
    row0: usize,
    chunk: &mut [i32],
    n: usize,
    tile_m: usize,
    tile_n: usize,
) {
    let rows = chunk.len() / n;
    let nwords = a.words_per_row;
    let full = nwords / CHUNK * CHUNK;
    let tail = nwords - full;
    let mut corr = [0i32; TILE_M];
    let mut tails = [[0u64; CHUNK]; TILE_M * MAX_BITS];
    let mut mt = 0;
    while mt < rows {
        let mt_end = (mt + tile_m).min(rows);
        for mi in mt..mt_end {
            corr[mi - mt] = qn * row_code_sum(a, row0 + mi);
            for ab in 0..a.bits {
                let plane = a.row_plane(row0 + mi, ab);
                let t = &mut tails[(mi - mt) * MAX_BITS + ab];
                *t = [0u64; CHUNK];
                t[..tail].copy_from_slice(&plane[full..]);
            }
        }
        let mut nt = 0;
        while nt < n {
            let nt_end = (nt + tile_n).min(n);
            for mi in mt..mt_end {
                let c = corr[mi - mt];
                for col in nt..nt_end {
                    let mut total = 0u64;
                    for wb in 0..w.bits {
                        let wplane = w.plane(col, wb);
                        for ab in 0..a.bits {
                            let aplane = a.row_plane(row0 + mi, ab);
                            let t = &tails[(mi - mt) * MAX_BITS + ab];
                            // SAFETY: `aplane` holds `full` (+tail) readable
                            // words, `t` is a CHUNK-word buffer, and
                            // `wplane` holds `plane_stride >= full + CHUNK·
                            // (tail > 0)` words — all in-bounds slices; NEON
                            // is guaranteed by this fn's target_feature.
                            let cnt = unsafe {
                                dot_plane_pair(
                                    aplane.as_ptr(),
                                    wplane.as_ptr(),
                                    full,
                                    t.as_ptr(),
                                    tail > 0,
                                )
                            };
                            total += cnt << (wb + ab);
                        }
                    }
                    chunk[mi * n + col] = (total as u32 as i32) - c;
                }
            }
            nt = nt_end;
        }
        mt = mt_end;
    }
}

/// Popcount-AND dot of one activation plane against one chunk-padded weight
/// plane (see the AVX2 twin for the accumulation-bound argument).
#[target_feature(enable = "neon")]
unsafe fn dot_plane_pair(
    a: *const u64,
    w: *const u64,
    full: usize,
    a_tail: *const u64,
    has_tail: bool,
) -> u64 {
    // SAFETY (whole body): the caller passes `a` with at least `full`
    // readable words, `a_tail` as a CHUNK-word buffer, and `w` with
    // `full` (+CHUNK when `has_tail`) readable words; all loads below stay
    // inside those bounds, and the NEON intrinsics are covered by this
    // fn's target_feature contract.
    unsafe {
        let mut total = 0u64;
        let mut bytes = vdupq_n_u8(0);
        let mut pending = 0usize;
        for j in 0..(full / CHUNK) {
            let av = vld1q_u64(a.add(j * CHUNK));
            let wv = vld1q_u64(w.add(j * CHUNK));
            let x = vreinterpretq_u8_u64(vandq_u64(av, wv));
            bytes = vaddq_u8(bytes, vcntq_u8(x));
            pending += 1;
            if pending == FLUSH {
                total += vaddlvq_u8(bytes) as u64;
                bytes = vdupq_n_u8(0);
                pending = 0;
            }
        }
        if has_tail {
            let av = vld1q_u64(a_tail);
            let wv = vld1q_u64(w.add(full));
            let x = vreinterpretq_u8_u64(vandq_u64(av, wv));
            bytes = vaddq_u8(bytes, vcntq_u8(x));
            pending += 1;
        }
        if pending > 0 {
            total += vaddlvq_u8(bytes) as u64;
        }
        total
    }
}

fn gemm_u8i8(a: &[u8], b: &[i8], m: usize, n: usize, k: usize, out: &mut [i32], nthreads: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    // SDOT lives in the optional dotprod extension (Armv8.2+), not baseline
    // NEON — gate on runtime detection and keep the portable loop as the
    // fallback so pre-8.2 cores still dispatch correctly.
    if !std::arch::is_aarch64_feature_detected!("dotprod") {
        crate::kernels::int8::gemm_u8i8_i32(a, b, m, n, k, out, nthreads);
        return;
    }
    threads::par_chunks_rows(out, n, nthreads, |row0, chunk| {
        // SAFETY: the dotprod detection above succeeded on this CPU (and
        // NEON is implied by reaching this registry entry), satisfying
        // `i8_rows_block_sdot`'s target_feature contract.
        unsafe { i8_rows_block_sdot(a, b, k, n, row0, chunk) }
    });
}

/// u8·i8 GEMM on the SDOT 4-way dot-accumulate (`vdotq_u32`): the signed
/// operand is offset to unsigned on the fly (`(b ⊕ 0x80) as u8 == b + 128`),
/// so `Σ a·b = Σ a·(b ⊕ 0x80) − 128·Σa` — exact in i64, narrowed to i32 at
/// the end. Per-u32-lane partial sums stay below 2³² for any reduction up to
/// k ≈ 2.6e5 (4·255·255 per step), far past any conv patch here.
#[target_feature(enable = "neon,dotprod")]
unsafe fn i8_rows_block_sdot(
    a: &[u8],
    b: &[i8],
    k: usize,
    n: usize,
    row0: usize,
    chunk: &mut [i32],
) {
    let kv = k / 16 * 16;
    for (i, orow) in chunk.chunks_mut(n).enumerate() {
        let arow = &a[(row0 + i) * k..(row0 + i + 1) * k];
        let rowsum: i64 = arow.iter().map(|&v| v as i64).sum();
        for (j, o) in orow.iter_mut().enumerate() {
            let brow = &b[j * k..(j + 1) * k];
            // SAFETY: every 16-byte load stays inside `arow`/`brow`
            // (`kk + 16 <= kv <= k`); NEON+dotprod are guaranteed by this
            // fn's target_feature contract (upheld at the dispatch check).
            unsafe {
                let bias = vdupq_n_u8(0x80);
                let mut accv = vdupq_n_u32(0);
                let mut kk = 0;
                while kk < kv {
                    let av = vld1q_u8(arow.as_ptr().add(kk));
                    let bv = vld1q_u8(brow.as_ptr().add(kk) as *const u8);
                    accv = vdotq_u32(accv, av, veorq_u8(bv, bias));
                    kk += 16;
                }
                let mut s = vaddlvq_u32(accv) as i64;
                for kk in kv..k {
                    s += arow[kk] as i64 * (brow[kk] as i64 + 128);
                }
                *o = (s - 128 * rowsum) as i32;
            }
        }
    }
}

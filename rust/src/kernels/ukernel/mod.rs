//! SIMD micro-kernel registry with runtime ISA dispatch.
//!
//! The paper's 2–5× speedups come from hand-vectorized popcount kernels;
//! this module is the dispatch layer that gets us there portably. Each
//! entry bundles the three GEMM inner kernels (bitserial popcount, int8,
//! fp32) for one instruction set, described by a [`UKernelDesc`] the
//! planner and cost model consume instead of global tile constants:
//!
//! * **scalar** — the tiled portable fallback (always available; the
//!   `u64::count_ones` bit-op machine of `kernels::bitserial`).
//! * **avx2** — x86-64 AVX2 nibble-LUT popcount bitserial GEMM and a
//!   widening `pmaddwd` int8 GEMM (compiled on x86-64, selected only when
//!   `avx2` is detected at runtime).
//! * **neon** — aarch64 `vcnt`-based popcount path (compiled on aarch64).
//!
//! Selection happens **once at compile time** (`select`/`selected_isa`):
//! the planner records the chosen ISA in the model, weights are prepacked
//! into the kernel's preferred [`WLayout`], and the executor calls straight
//! through a resolved fn pointer — no per-request detection or lookup.
//! `DLRT_FORCE_ISA={scalar,neon,avx2}` pins the choice (error if the host
//! can't run it); tests use [`available_isas`] to sweep every host path.

use crate::dlrt::tensor::Packed;

mod scalar;

#[cfg(target_arch = "x86_64")]
mod avx2;

#[cfg(target_arch = "aarch64")]
mod neon;

/// Instruction sets the registry knows about. `Scalar` is always available;
/// the SIMD entries exist only on their architecture and are handed out
/// only when runtime feature detection succeeds.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Isa {
    Scalar,
    Neon,
    Avx2,
}

impl Isa {
    /// Stable lowercase name (CLI/env/format tag).
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Neon => "neon",
            Isa::Avx2 => "avx2",
        }
    }

    /// Parse a `DLRT_FORCE_ISA` / CLI value.
    pub fn parse(s: &str) -> Result<Isa, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Ok(Isa::Scalar),
            "neon" => Ok(Isa::Neon),
            "avx2" => Ok(Isa::Avx2),
            other => Err(format!(
                "unknown ISA '{other}': valid values are scalar, neon, avx2 \
                 (detected on this host: {})",
                detected_list()
            )),
        }
    }
}

/// Static description of one micro-kernel: the tile blocking the GEMM uses
/// (consumed by the planner's cost model in place of the old global
/// `TILE_M`/`TILE_N` constants) and how far its inner loop unrolls the
/// packed-word reduction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct UKernelDesc {
    pub isa: Isa,
    /// Activation-row (M) tile kept cache-resident per block.
    pub tile_m: usize,
    /// Output-channel (N) tile walked per M-tile; also the prepack group.
    pub tile_n: usize,
    /// Packed `u64` words consumed per inner-loop iteration.
    pub k_unroll: usize,
}

/// Weight bit-plane storage layout, recorded per conv in the `.dlrt` format
/// and matched against the loading host's selected kernel (mismatches are
/// repacked on load).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WLayout {
    /// `Packed`-compatible: plane `(row, bit)` at `(row*bits + bit) * wpr`.
    RowMajor,
    /// N-tile walk order for a vector kernel: rows grouped `tile_n` at a
    /// time, every plane zero-padded to a multiple of `chunk` words so the
    /// inner loop streams whole vectors without tail branches.
    TileN { tile_n: usize, chunk: usize },
}

/// Prepacked weight bit-planes in a kernel-chosen [`WLayout`].
///
/// Plane `(row, bit)` lives at `(row*bits + bit) * plane_stride`; for
/// `RowMajor` the stride equals `words_per_row` (identical to [`Packed`]),
/// for `TileN` it is rounded up to the kernel's vector chunk with zero
/// padding (AND-with-zero contributes no popcount, so padded reads are
/// value-neutral by construction).
#[derive(Clone, Debug, PartialEq)]
pub struct PackedW {
    pub rows: usize,
    pub k: usize,
    pub bits: usize,
    /// Meaningful words per plane (`ceil(k / 64)`).
    pub words_per_row: usize,
    /// Stored words per plane (`>= words_per_row`).
    pub plane_stride: usize,
    pub layout: WLayout,
    pub data: Vec<u64>,
}

impl PackedW {
    /// Repack a row-major [`Packed`] into `layout`.
    pub fn from_packed(p: &Packed, layout: WLayout) -> PackedW {
        let wpr = p.words_per_row;
        let plane_stride = match layout {
            WLayout::RowMajor => wpr,
            WLayout::TileN { chunk, .. } => wpr.div_ceil(chunk.max(1)) * chunk.max(1),
        };
        let mut data = vec![0u64; p.rows * p.bits * plane_stride];
        for r in 0..p.rows {
            for b in 0..p.bits {
                let dst = (r * p.bits + b) * plane_stride;
                data[dst..dst + wpr].copy_from_slice(p.row_plane(r, b));
            }
        }
        PackedW {
            rows: p.rows,
            k: p.k,
            bits: p.bits,
            words_per_row: wpr,
            plane_stride,
            layout,
            data,
        }
    }

    /// Recover the canonical row-major [`Packed`] (reference interpreter,
    /// `.dlrt` cross-ISA repacking). Allocates; never on the serving path.
    pub fn to_row_major(&self) -> Packed {
        let wpr = self.words_per_row;
        let mut p = Packed::new_zeroed(self.rows, self.k, self.bits);
        for r in 0..self.rows {
            for b in 0..self.bits {
                let src = (r * self.bits + b) * self.plane_stride;
                let dst = (r * self.bits + b) * wpr;
                p.data[dst..dst + wpr].copy_from_slice(&self.data[src..src + wpr]);
            }
        }
        p
    }

    /// One stored plane (`plane_stride` words, padding included).
    #[inline]
    pub fn plane(&self, row: usize, bit: usize) -> &[u64] {
        let base = (row * self.bits + bit) * self.plane_stride;
        &self.data[base..base + self.plane_stride]
    }

    /// Bytes of packed weight storage (model-size accounting).
    pub fn storage_bytes(&self) -> usize {
        self.data.len() * 8
    }
}

/// Bitserial GEMM: packed unsigned activations × prepacked offset-encoded
/// weights → i32 (same contract as `bitserial::gemm_bitserial`). The first
/// argument carries the tile geometry to run with — normally the kernel's
/// own `desc`, or a tuned override from the schedule DB (`dlrt tune`); the
/// kernel clamps it to whatever its register blocking can honor.
pub type BitGemmFn = fn(desc: &UKernelDesc, a: &Packed, w: &PackedW, w_bits_signed: usize, out: &mut [i32], nthreads: usize);
/// int8 GEMM: `a` m×k u8 codes, `b` n×k i8 codes, i32 accumulate.
pub type I8GemmFn = fn(a: &[u8], b: &[i8], m: usize, n: usize, k: usize, out: &mut [i32], nthreads: usize);
/// fp32 GEMM: `a` m×k, `b` n×k (transposed B), f32 accumulate.
pub type F32GemmFn = fn(a: &[f32], b: &[f32], m: usize, n: usize, k: usize, out: &mut [f32], nthreads: usize);

/// One registry entry: the three GEMM inner kernels for one ISA.
pub struct UKernel {
    pub desc: UKernelDesc,
    pub gemm_bit: BitGemmFn,
    pub gemm_u8i8: I8GemmFn,
    pub gemm_f32: F32GemmFn,
}

impl UKernel {
    /// The weight bit-plane layout this kernel's bitserial GEMM consumes
    /// under its default (untuned) geometry.
    pub fn weight_layout(&self) -> WLayout {
        self.weight_layout_for(&self.desc)
    }

    /// The layout for an overridden geometry (a tuned schedule): same rule,
    /// but tile/chunk come from `desc` instead of the static defaults.
    pub fn weight_layout_for(&self, desc: &UKernelDesc) -> WLayout {
        match self.desc.isa {
            Isa::Scalar => WLayout::RowMajor,
            Isa::Neon | Isa::Avx2 => {
                WLayout::TileN { tile_n: desc.tile_n, chunk: desc.k_unroll }
            }
        }
    }
}

/// The packed-word chunk the ISA's bitserial inner loop natively consumes
/// per vector step; tuned `k_unroll` values must be a positive multiple of
/// this so padded planes keep satisfying the kernel's stride asserts.
pub fn native_chunk(isa: Isa) -> usize {
    match isa {
        Isa::Scalar => 1,
        Isa::Neon => 2,
        Isa::Avx2 => 4,
    }
}

/// Host support for `isa`, checked at runtime (not compile time): the AVX2
/// entry is compiled into every x86-64 binary but only offered when the CPU
/// reports the feature.
pub fn host_supports(isa: Isa) -> bool {
    // Miri interprets MIR and cannot execute vendor intrinsics: only the
    // scalar kernel exists under the interpreter, regardless of what the
    // compile-time target features claim.
    if cfg!(miri) {
        return isa == Isa::Scalar;
    }
    match isa {
        Isa::Scalar => true,
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => std::arch::is_x86_feature_detected!("avx2"),
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => std::arch::is_aarch64_feature_detected!("neon"),
        #[allow(unreachable_patterns)]
        _ => false,
    }
}

/// Every ISA this host can actually run, best first (ends with `Scalar`).
pub fn available_isas() -> Vec<Isa> {
    [Isa::Avx2, Isa::Neon, Isa::Scalar].into_iter().filter(|&i| host_supports(i)).collect()
}

/// Comma-joined names of the host-detected ISAs, for error messages.
fn detected_list() -> String {
    available_isas().iter().map(|i| i.name()).collect::<Vec<_>>().join(", ")
}

/// The registry entry for `isa`, or `None` if this host can't run it.
pub fn kernel_for(isa: Isa) -> Option<&'static UKernel> {
    if !host_supports(isa) {
        return None;
    }
    match isa {
        Isa::Scalar => Some(&scalar::KERNEL),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => Some(&avx2::KERNEL),
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => Some(&neon::KERNEL),
        #[allow(unreachable_patterns)]
        _ => None,
    }
}

/// Pick an ISA: `force` pins it (error when the host can't run it);
/// otherwise the best host-supported entry wins.
pub fn select(force: Option<Isa>) -> Result<Isa, String> {
    match force {
        Some(isa) => {
            if host_supports(isa) {
                Ok(isa)
            } else {
                Err(format!(
                    "DLRT_FORCE_ISA={} is not supported on this host: valid values are \
                     scalar, neon, avx2; detected on this host: {}",
                    isa.name(),
                    detected_list()
                ))
            }
        }
        None => Ok(available_isas()[0]),
    }
}

/// The process-default ISA: `DLRT_FORCE_ISA` if set (rejecting values the
/// host can't run), else the best detected entry. Read once and cached —
/// compile-time selection must not shift between layers of one model.
pub fn selected_isa() -> Result<Isa, String> {
    static SEL: std::sync::OnceLock<Result<Isa, String>> = std::sync::OnceLock::new();
    SEL.get_or_init(|| {
        let force = match std::env::var("DLRT_FORCE_ISA") {
            Ok(v) if !v.trim().is_empty() => Some(Isa::parse(&v)?),
            _ => None,
        };
        select(force)
    })
    .clone()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dlrt::graph::qp_qn;
    use crate::kernels::bitserial::{gemm_bitserial, pack_rows_u8, pack_weights_offset};
    use crate::util::rng::Rng;

    #[test]
    fn scalar_is_always_available_and_last() {
        let isas = available_isas();
        assert_eq!(*isas.last().unwrap(), Isa::Scalar);
        assert!(kernel_for(Isa::Scalar).is_some());
        for &isa in &isas {
            let k = kernel_for(isa).expect("available ISA must have a kernel");
            assert_eq!(k.desc.isa, isa);
            assert!(k.desc.tile_m > 0 && k.desc.tile_n > 0 && k.desc.k_unroll > 0);
        }
    }

    #[test]
    fn parse_round_trips_and_rejects_junk() {
        for isa in [Isa::Scalar, Isa::Neon, Isa::Avx2] {
            assert_eq!(Isa::parse(isa.name()).unwrap(), isa);
        }
        assert_eq!(Isa::parse("AVX2").unwrap(), Isa::Avx2);
        let err = Isa::parse("sse9").unwrap_err();
        assert!(err.contains("sse9"), "bad value echoed: {err}");
        assert!(err.contains("scalar, neon, avx2"), "valid values listed: {err}");
        assert!(err.contains("detected on this host"), "detected ISAs listed: {err}");
    }

    #[test]
    fn select_rejects_unsupported_force() {
        // at most one of neon/avx2 exists on any host, so the other errors
        let bogus = if cfg!(target_arch = "x86_64") { Isa::Neon } else { Isa::Avx2 };
        let err = select(Some(bogus)).unwrap_err();
        assert!(err.contains("DLRT_FORCE_ISA"), "names the env var: {err}");
        assert!(err.contains("scalar, neon, avx2"), "valid values listed: {err}");
        assert!(err.contains(available_isas()[0].name()), "detected ISAs listed: {err}");
        assert_eq!(select(Some(Isa::Scalar)).unwrap(), Isa::Scalar);
        assert_eq!(select(None).unwrap(), available_isas()[0]);
    }

    #[test]
    fn packedw_round_trips_every_layout() {
        let mut rng = Rng::new(91);
        for &(rows, k, bits) in &[(1usize, 1usize, 1usize), (5, 64, 2), (17, 130, 3), (3, 257, 8)] {
            let codes: Vec<u8> = (0..rows * k).map(|_| rng.usize(1 << bits) as u8).collect();
            let p = pack_rows_u8(&codes, rows, k, bits);
            for layout in [
                WLayout::RowMajor,
                WLayout::TileN { tile_n: 8, chunk: 4 },
                WLayout::TileN { tile_n: 4, chunk: 2 },
            ] {
                let pw = PackedW::from_packed(&p, layout);
                assert_eq!(pw.layout, layout);
                if let WLayout::TileN { chunk, .. } = layout {
                    assert_eq!(pw.plane_stride % chunk, 0);
                }
                assert_eq!(pw.to_row_major(), p, "{rows}x{k}@{bits} {layout:?}");
            }
        }
    }

    /// Boundary-shape sweep for every host-compiled ISA against the scalar
    /// row-major reference: K/N off vector-width multiples, single-row M,
    /// bits ∈ 1..=8, padded plane tails.
    #[test]
    fn every_host_isa_matches_scalar_reference_on_boundary_shapes() {
        let mut rng = Rng::new(417);
        let shapes = [
            (1usize, 1usize, 1usize),    // minimal everything
            (1, 3, 63),                  // single row, K just under a word
            (2, 5, 64),                  // exact word
            (3, 4, 65),                  // word + 1
            (5, 17, 130),                // N off tile, K off chunk
            (2, 16, 256),                // exact chunk multiples
            (4, 7, 300),                 // ragged both ways
        ];
        for isa in available_isas() {
            let uk = kernel_for(isa).unwrap();
            let layout = uk.weight_layout();
            for &(m, n, k) in &shapes {
                for wb in 1..=8usize {
                    // pair every weight width with a low and a high act width
                    for ab in [1usize, if wb < 5 { 2 } else { 7 }] {
                        let (qp, qn) = qp_qn(wb as u8, true);
                        let a: Vec<u8> =
                            (0..m * k).map(|_| rng.usize(1 << ab) as u8).collect();
                        let w: Vec<i32> = (0..n * k)
                            .map(|_| rng.range(-(qn as i64), qp as i64 + 1) as i32)
                            .collect();
                        let ap = pack_rows_u8(&a, m, k, ab);
                        let wp = pack_weights_offset(&w, n, k, wb);
                        let pw = PackedW::from_packed(&wp, layout);
                        let mut want = vec![0i32; m * n];
                        gemm_bitserial(&ap, &wp, wb, &mut want, 1);
                        for threads in [1usize, 3] {
                            let mut got = vec![0i32; m * n];
                            (uk.gemm_bit)(&uk.desc, &ap, &pw, wb, &mut got, threads);
                            assert_eq!(
                                got, want,
                                "{} m={m} n={n} k={k} {ab}A{wb}W t={threads}",
                                isa.name()
                            );
                        }
                    }
                }
            }
        }
    }

    /// Tuned-geometry sweep: deliberately odd `UKernelDesc` overrides
    /// (prime tiles, doubled k_unroll) against the scalar reference —
    /// tile blocking must never change the integer result, and the
    /// prepacked layout must follow the override, not the default.
    #[test]
    fn tuned_desc_overrides_stay_bit_exact() {
        let mut rng = Rng::new(24_601);
        for isa in available_isas() {
            let uk = kernel_for(isa).unwrap();
            let overrides = [
                UKernelDesc { tile_m: 5, tile_n: 3, ..uk.desc },
                UKernelDesc { tile_m: 1, tile_n: 1, ..uk.desc },
                UKernelDesc { tile_m: 64, tile_n: 32, k_unroll: uk.desc.k_unroll * 2, ..uk.desc },
            ];
            for desc in &overrides {
                let layout = uk.weight_layout_for(desc);
                for &(m, n, k) in &[(1usize, 1usize, 1usize), (5, 17, 130), (4, 7, 300)] {
                    for wb in [1usize, 2, 8] {
                        let (qp, qn) = qp_qn(wb as u8, true);
                        let ab = 2usize;
                        let a: Vec<u8> =
                            (0..m * k).map(|_| rng.usize(1 << ab) as u8).collect();
                        let w: Vec<i32> = (0..n * k)
                            .map(|_| rng.range(-(qn as i64), qp as i64 + 1) as i32)
                            .collect();
                        let ap = pack_rows_u8(&a, m, k, ab);
                        let wp = pack_weights_offset(&w, n, k, wb);
                        let pw = PackedW::from_packed(&wp, layout);
                        let mut want = vec![0i32; m * n];
                        gemm_bitserial(&ap, &wp, wb, &mut want, 1);
                        for threads in [1usize, 3] {
                            let mut got = vec![0i32; m * n];
                            (uk.gemm_bit)(desc, &ap, &pw, wb, &mut got, threads);
                            assert_eq!(
                                got, want,
                                "{} {desc:?} m={m} n={n} k={k} t={threads}",
                                isa.name()
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn every_host_isa_int8_matches_scalar() {
        let mut rng = Rng::new(91_011);
        for isa in available_isas() {
            let uk = kernel_for(isa).unwrap();
            for &(m, n, k) in
                &[(1usize, 1usize, 1usize), (1, 3, 15), (2, 5, 16), (3, 4, 17), (5, 9, 130)]
            {
                let a: Vec<u8> = (0..m * k).map(|_| rng.usize(256) as u8).collect();
                let b: Vec<i8> = (0..n * k).map(|_| rng.range(-128, 128) as i8).collect();
                let mut want = vec![0i32; m * n];
                crate::kernels::int8::gemm_u8i8_i32(&a, &b, m, n, k, &mut want, 1);
                for threads in [1usize, 3] {
                    let mut got = vec![0i32; m * n];
                    (uk.gemm_u8i8)(&a, &b, m, n, k, &mut got, threads);
                    assert_eq!(got, want, "{} m={m} n={n} k={k} t={threads}", isa.name());
                }
            }
        }
    }

    #[test]
    fn every_host_isa_f32_matches_portable() {
        let mut rng = Rng::new(77_000);
        for isa in available_isas() {
            let uk = kernel_for(isa).unwrap();
            let (m, n, k) = (7usize, 5usize, 33usize);
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..n * k).map(|_| rng.normal()).collect();
            let mut want = vec![0.0f32; m * n];
            crate::kernels::fp32::gemm_rowmajor_bt(&a, &b, m, n, k, &mut want, 1);
            let mut got = vec![0.0f32; m * n];
            (uk.gemm_f32)(&a, &b, m, n, k, &mut got, 1);
            assert_eq!(got, want, "{}: fp32 path must stay the portable kernel", isa.name());
        }
    }
}

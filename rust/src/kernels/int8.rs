//! INT8 engine: i8×i8 → i32 GEMM — stands in for the TFLite/XNNPACK and
//! ONNX Runtime INT8 baselines the paper compares against.
//!
//! Symmetric per-tensor quantization: `x ≈ s_x * xq`, `w ≈ s_w * wq`, so
//! `conv(x, w) ≈ s_x * s_w * Σ xq*wq` with exact i32 accumulation
//! (k < 2^16 per layer keeps i32 safely un-overflowed at 8 bits).

use crate::util::threads;

/// `a`: m×k (u8 codes, unsigned activations), `b`: n×k (i8 weights),
/// `out[m][n] = Σ_k a*b` in i32.
pub fn gemm_u8i8_i32(a: &[u8], b: &[i8], m: usize, n: usize, k: usize,
                     out: &mut [i32], nthreads: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    threads::par_chunks_rows(out, n, nthreads, |row0, chunk| {
        // each worker owns a disjoint &mut block of whole output rows
        for (i, orow) in chunk.chunks_mut(n).enumerate() {
            let arow = &a[(row0 + i) * k..(row0 + i + 1) * k];
            for (j, o) in orow.iter_mut().enumerate() {
                let brow = &b[j * k..(j + 1) * k];
                let mut s0: i32 = 0;
                let mut s1: i32 = 0;
                let mut kk = 0;
                // 2-way unrolled dot; autovectorizes to pmaddwd-ish code
                while kk + 2 <= k {
                    s0 += arow[kk] as i32 * brow[kk] as i32;
                    s1 += arow[kk + 1] as i32 * brow[kk + 1] as i32;
                    kk += 2;
                }
                if kk < k {
                    s0 += arow[kk] as i32 * brow[kk] as i32;
                }
                *o = s0 + s1;
            }
        }
    });
}

/// Quantize weights to i8 codes with symmetric scale (returns codes, scale).
pub fn quantize_weights_i8(w: &[f32]) -> (Vec<i8>, f32) {
    let amax = w.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    let s = (amax / 127.0).max(1e-12);
    let codes = w.iter().map(|v| (v / s).round().clamp(-127.0, 127.0) as i8).collect();
    (codes, s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn naive(a: &[u8], b: &[i8], m: usize, n: usize, k: usize) -> Vec<i32> {
        let mut out = vec![0i32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[i * n + j] =
                    (0..k).map(|kk| a[i * k + kk] as i32 * b[j * k + kk] as i32).sum();
            }
        }
        out
    }

    #[test]
    fn matches_naive_property() {
        prop::check(60, |rng, _| {
            let m = rng.usize(20) + 1;
            let n = rng.usize(20) + 1;
            let k = rng.usize(130) + 1;
            let a: Vec<u8> = (0..m * k).map(|_| rng.usize(256) as u8).collect();
            let b: Vec<i8> = (0..n * k).map(|_| rng.range(-128, 128) as i8).collect();
            let mut got = vec![0; m * n];
            gemm_u8i8_i32(&a, &b, m, n, k, &mut got, 1);
            prop::ensure(got == naive(&a, &b, m, n, k), "int8 gemm mismatch")
        });
    }

    #[test]
    fn threaded_matches_single() {
        prop::check(10, |rng, _| {
            let (m, n, k) = (rng.usize(40) + 8, rng.usize(16) + 1, rng.usize(64) + 1);
            let a: Vec<u8> = (0..m * k).map(|_| rng.usize(4) as u8).collect();
            let b: Vec<i8> = (0..n * k).map(|_| rng.range(-2, 2) as i8).collect();
            let mut g1 = vec![0; m * n];
            let mut g4 = vec![0; m * n];
            gemm_u8i8_i32(&a, &b, m, n, k, &mut g1, 1);
            gemm_u8i8_i32(&a, &b, m, n, k, &mut g4, 4);
            prop::ensure(g1 == g4, "thread count changed result")
        });
    }

    #[test]
    fn weight_quantization_bounds() {
        let w = vec![-1.0, 0.5, 0.25, 1.0];
        let (codes, s) = quantize_weights_i8(&w);
        assert_eq!(codes[0], -127);
        assert_eq!(codes[3], 127);
        assert!((s - 1.0 / 127.0).abs() < 1e-9);
        // zero-safe
        let (codes, s) = quantize_weights_i8(&[0.0; 4]);
        assert!(codes.iter().all(|&c| c == 0) && s > 0.0);
    }
}

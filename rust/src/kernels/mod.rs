//! Compute kernels: the paper's bitserial engine plus FP32/INT8 baselines.
//!
//! All convolutions share the im2col → GEMM structure (as the paper's
//! kernels do); the engines differ in how the GEMM inner product is
//! computed:
//!
//! * [`bitserial`] — bitplane-packed `u64` words, `AND` + `POPCOUNT`
//!   (the paper's contribution; Neon `VCNT` ≙ `u64::count_ones`).
//! * [`fp32`] — blocked float GEMM (the "optimized FP32 baseline").
//! * [`int8`] — i8×i8→i32 GEMM (the TFLite/ONNX-Runtime INT8 analog).
//! * [`ukernel`] — the SIMD micro-kernel registry: per-ISA GEMM inner
//!   kernels (NEON / AVX2 / portable scalar) selected once at plan time by
//!   runtime CPU feature detection, with tile-order weight prepacking.

pub mod bitserial;
pub mod elementwise;
pub mod fp32;
pub mod im2col;
pub mod int8;
pub mod pool;
pub mod ukernel;

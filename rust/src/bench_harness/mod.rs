//! Benchmark harness (criterion stand-in): robust timing + paper tables.
//!
//! Every `cargo bench` target uses [`bench_ms`] (warmup + median/MAD over
//! repeats) and renders a [`Table`] that prints the paper's reported value
//! next to ours, plus writes a JSON record under `target/bench_results/`
//! for EXPERIMENTS.md bookkeeping.

use std::time::Instant;

use crate::util::json::{arr, num, obj, s, Json};

/// Timing result in milliseconds.
#[derive(Clone, Copy, Debug)]
pub struct Timing {
    pub median_ms: f64,
    pub mad_ms: f64,
    pub reps: usize,
}

/// Run `f` `reps` times after `warmup` runs; report median + MAD.
pub fn bench_ms<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<f64> = (0..reps.max(1))
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64() * 1e3
        })
        .collect();
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mut devs: Vec<f64> = samples.iter().map(|v| (v - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Timing { median_ms: median, mad_ms: devs[devs.len() / 2], reps: samples.len() }
}

/// Adaptive repeat count: aim for ~`budget_ms` of total measurement.
pub fn reps_for(first_run_ms: f64, budget_ms: f64) -> usize {
    ((budget_ms / first_run_ms.max(0.01)) as usize).clamp(3, 200)
}

/// A paper-style results table.
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|c| c.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        println!("\n== {} ==", self.title);
        let header: Vec<String> = self
            .columns
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:w$}", c, w = widths[i]))
            .collect();
        println!("| {} |", header.join(" | "));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        println!("|-{}-|", sep.join("-|-"));
        for row in &self.rows {
            let cells: Vec<String> = row
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:w$}", c, w = widths[i]))
                .collect();
            println!("| {} |", cells.join(" | "));
        }
    }

    /// Persist under `target/bench_results/<name>.json`.
    pub fn save_json(&self, name: &str) {
        let dir = std::path::Path::new("target/bench_results");
        let _ = std::fs::create_dir_all(dir);
        let j = obj(vec![
            ("title", s(&self.title)),
            ("columns", arr(self.columns.iter().map(|c| s(c)).collect())),
            ("rows", arr(self.rows.iter()
                .map(|r| arr(r.iter().map(|c| s(c)).collect()))
                .collect())),
        ]);
        let _ = std::fs::write(dir.join(format!("{name}.json")), j.to_string());
    }
}

/// Format helpers for table cells.
pub fn ms(v: f64) -> String {
    if v >= 100.0 {
        format!("{v:.0} ms")
    } else if v >= 1.0 {
        format!("{v:.1} ms")
    } else {
        format!("{:.2} ms", v)
    }
}

pub fn speedup(base: f64, ours: f64) -> String {
    format!("{:.2}x", base / ours)
}

pub fn fps(ms_v: f64) -> String {
    format!("{:.1} FPS", 1000.0 / ms_v)
}

/// Record a perf-iteration entry (EXPERIMENTS.md §Perf bookkeeping).
pub fn record_perf(name: &str, entries: &[(&str, f64)]) {
    let dir = std::path::Path::new("target/bench_results");
    let _ = std::fs::create_dir_all(dir);
    let j = Json::Obj(
        entries
            .iter()
            .map(|(k, v)| (k.to_string(), num(*v)))
            .collect(),
    );
    let _ = std::fs::write(dir.join(format!("perf_{name}.json")), j.to_string());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let t = bench_ms(1, 5, || {
            std::hint::black_box((0..10_000).sum::<u64>());
        });
        assert!(t.median_ms >= 0.0);
        assert_eq!(t.reps, 5);
    }

    #[test]
    fn reps_clamped() {
        assert_eq!(reps_for(1000.0, 500.0), 3);
        assert_eq!(reps_for(0.0001, 1e9), 200);
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new("Test", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        t.print();
        assert_eq!(t.rows.len(), 1);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_row() {
        let mut t = Table::new("Test", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(ms(250.0), "250 ms");
        assert_eq!(ms(12.34), "12.3 ms");
        assert_eq!(ms(0.5), "0.50 ms");
        assert_eq!(speedup(100.0, 50.0), "2.00x");
        assert_eq!(fps(100.0), "10.0 FPS");
    }
}

//! `dlrt` — DeepliteRT command-line interface.
//!
//! ```text
//! dlrt compile <model_dir> --out <file.dlrt> [--engine auto|fp32|int8]
//! dlrt run     <file.dlrt | model_dir> [--threads N] [--reps N] [--batch B]
//! dlrt inspect [<file.dlrt | model_dir>] [--model NAME --res N] [--layers]
//!              [--plan]                  # dump the lowered execution plan
//! dlrt bench   [--model resnet18|resnet50|vgg16_ssd|yolov5n|s|m]
//!              [--res N] [--engine auto|fp32|int8] [--threads N] [--reps N]
//! dlrt cost    [--model ...] [--res N] [--cpu a53|a72|a57] [--threads N]
//! dlrt serve   [--model ...] [--requests N] [--max-batch B] [--workers W]
//! dlrt pjrt    <artifact_stem>        # run a JAX-AOT HLO artifact
//! ```

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use dlrt::bench_harness::{bench_ms, ms, reps_for, Table};
use dlrt::compiler::{compile_graph, load_arch, EngineChoice};
use dlrt::coordinator::{InferenceServer, ServerConfig};
use dlrt::costmodel::{self, cpu_by_name, EngineKind};
use dlrt::dlrt::format;
use dlrt::dlrt::graph::QCfg;
use dlrt::exec::Executor;
use dlrt::models;
use dlrt::util::cli::Args;
use dlrt::util::rng::Rng;
use dlrt::Tensor;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print_usage();
        std::process::exit(2);
    }
    let cmd = argv[0].clone();
    let args = match Args::parse(argv[1..].to_vec()) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let result = match cmd.as_str() {
        "compile" => cmd_compile(&args),
        "run" => cmd_run(&args),
        "inspect" => cmd_inspect(&args),
        "bench" => cmd_bench(&args),
        "cost" => cmd_cost(&args),
        "serve" => cmd_serve(&args),
        "pjrt" => cmd_pjrt(&args),
        "help" | "--help" => {
            print_usage();
            Ok(())
        }
        other => {
            eprintln!("unknown command {other:?}");
            print_usage();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_usage() {
    eprintln!("dlrt — ultra-low-bit bitserial inference runtime (DeepliteRT repro)");
    eprintln!("commands: compile | run | inspect | bench | cost | serve | pjrt");
    eprintln!("see rust/src/main.rs docs or README.md for flags");
}

/// Build a model either from an exported dir/.dlrt or a native builder name.
fn load_model(args: &Args, engine: EngineChoice) -> Result<(String, dlrt::exec::CompiledModel)> {
    if let Some(path) = args.positional.first() {
        let p = Path::new(path);
        if p.extension().map(|e| e == "dlrt").unwrap_or(false) {
            return Ok((path.clone(), format::load(p)?));
        }
        let g = load_arch(p)?;
        return Ok((g.name.clone(), compile_graph(&g, engine)?));
    }
    let name = args.get_or("model", "resnet18").to_string();
    let res = args.usize_or("res", default_res(&name))?;
    let g = build_named(&name, res, args)?;
    Ok((format!("{name}@{res}"), compile_graph(&g, engine)?))
}

fn default_res(model: &str) -> usize {
    match model {
        "vgg16_ssd" => 300,
        m if m.starts_with("yolov5") => 320,
        _ => 224,
    }
}

fn build_named(name: &str, res: usize, args: &Args) -> Result<dlrt::Graph> {
    let wb = args.usize_or("w-bits", 2)? as u8;
    let ab = args.usize_or("a-bits", 2)? as u8;
    let q = QCfg::new(ab, wb);
    let wm = args.f64_or("width-mult", 1.0)? as f32;
    Ok(match name {
        "resnet18" => models::build_resnet(18, 1000, res, wm, q, 0),
        "resnet50" => models::build_resnet(50, 1000, res, wm, q, 0),
        "vgg16_ssd" => models::build_vgg16_ssd(21, res, wm, q, 0),
        "yolov5n" => models::build_yolov5("n", 80, res, wm, q, 0),
        "yolov5s" => models::build_yolov5("s", 80, res, wm, q, 0),
        "yolov5m" => models::build_yolov5("m", 80, res, wm, q, 0),
        other => bail!("unknown model {other:?}"),
    })
}

fn random_input(model: &dlrt::exec::CompiledModel, batch: usize, seed: u64) -> Tensor {
    let s = model.graph.input_shape;
    let mut rng = Rng::new(seed);
    let mut t = Tensor::zeros(vec![batch, s[1], s[2], s[3]]);
    for v in t.data.iter_mut() {
        *v = rng.f32();
    }
    t
}

fn cmd_compile(args: &Args) -> Result<()> {
    let dir = args.positional.first().context("usage: dlrt compile <model_dir> --out f.dlrt")?;
    let engine = EngineChoice::parse(args.get_or("engine", "auto"))?;
    let g = load_arch(Path::new(dir))?;
    let model = compile_graph(&g, engine)?;
    let out = PathBuf::from(args.get_or("out", "model.dlrt"));
    format::save(&model, &out)?;
    let fp32_bytes: usize = g.weights.values().map(|w| w.w.len() * 4).sum();
    println!("compiled {} -> {}", g.name, out.display());
    println!("engines: {:?}", model.engine_summary());
    println!(
        "weights: {} B packed vs {} B fp32 ({:.2}x compression)",
        model.weight_bytes(),
        fp32_bytes,
        fp32_bytes as f64 / model.weight_bytes() as f64
    );
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let engine = EngineChoice::parse(args.get_or("engine", "auto"))?;
    let (name, model) = load_model(args, engine)?;
    let threads = args.usize_or("threads", 1)?;
    let batch = args.usize_or("batch", 1)?;
    let mut ex = Executor::new(threads);
    let x = random_input(&model, batch, 1);
    let outs = ex.run(&model, &x)?;
    let t0 = std::time::Instant::now();
    ex.run(&model, &x)?;
    let first_ms = t0.elapsed().as_secs_f64() * 1e3;
    let reps = args.usize_or("reps", reps_for(first_ms, 2000.0))?;
    let timing = bench_ms(1, reps, || {
        ex.run(&model, &x).unwrap();
    });
    println!("model   : {name}");
    println!("engines : {:?}", model.engine_summary());
    println!("input   : {:?}", x.shape);
    for (i, o) in outs.iter().enumerate() {
        let mn = o.data.iter().cloned().fold(f32::INFINITY, f32::min);
        let mx = o.data.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        println!("output{} : {:?} range [{mn:.4}, {mx:.4}]", i, o.shape);
    }
    println!("latency : {} (median of {}, ±{})", ms(timing.median_ms), timing.reps,
             ms(timing.mad_ms));
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    // accepts a .dlrt file / model dir positionally, or a native builder
    // via --model NAME --res N (so CI can exercise plan lowering without
    // exported artifacts)
    let engine = EngineChoice::parse(args.get_or("engine", "auto"))?;
    let (_source, model) = load_model(args, engine)?;
    let g = &model.graph;
    let peak = dlrt::exec::planner::peak_live_elems(g)?;
    println!("model   : {}", g.name);
    println!("input   : {} {:?}", g.input_name, g.input_shape);
    println!("nodes   : {} ({} convs)", g.nodes.len(), g.conv_nodes().count());
    println!("outputs : {:?}", g.outputs);
    println!("engines : {:?}", model.engine_summary());
    println!("weights : {} bytes", model.weight_bytes());
    println!("peak act: {peak} f32 elems");
    if args.flag("layers") {
        for n in g.conv_nodes() {
            let c = &model.convs[&n.name];
            println!("  {:<24} {:<9} scale[{}]", n.name, c.kernel.engine_name(),
                     c.scale.len());
        }
    }
    if args.flag("plan") {
        let p = &model.plan;
        println!(
            "plan    : {} instrs ({} fused epilogues, {} in-place), {} slots",
            p.instrs.len(),
            p.fused_instrs(),
            p.in_place_instrs(),
            p.slot_sizes.len()
        );
        println!(
            "arena   : {} f32 elems ({} bytes) @ batch {} — interpreter peak {} ({} bytes)",
            p.arena_elems(p.nominal_batch),
            4 * p.arena_elems(p.nominal_batch),
            p.nominal_batch,
            peak,
            4 * peak
        );
        for (i, ins) in p.instrs.iter().enumerate() {
            let fused = match ins.fused {
                Some(a) => format!(" +{}", a.name()),
                None => String::new(),
            };
            let mode = if ins.in_place { " (in-place)" } else { "" };
            println!(
                "  {i:>3}: {:<12} {:<24} in={:?} out={} {:?}{fused}{mode}",
                ins.op.name(),
                ins.name,
                ins.in_slots,
                ins.out_slot,
                ins.out_tail
            );
        }
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let engine = EngineChoice::parse(args.get_or("engine", "auto"))?;
    let (name, model) = load_model(args, engine)?;
    let threads = args.usize_or("threads", 1)?;
    let mut ex = Executor::new(threads);
    let x = random_input(&model, 1, 1);
    ex.run(&model, &x)?; // warm
    let t0 = std::time::Instant::now();
    ex.run(&model, &x)?;
    let first = t0.elapsed().as_secs_f64() * 1e3;
    let reps = args.usize_or("reps", reps_for(first, 5000.0))?;
    let timing = bench_ms(0, reps, || {
        ex.run(&model, &x).unwrap();
    });
    let mut table = Table::new(&format!("dlrt bench — {name}"),
                               &["engine", "threads", "median", "MAD", "reps"]);
    table.row(vec![
        format!("{:?}", model.engine_summary()),
        threads.to_string(),
        ms(timing.median_ms),
        ms(timing.mad_ms),
        timing.reps.to_string(),
    ]);
    table.print();
    Ok(())
}

fn cmd_cost(args: &Args) -> Result<()> {
    let name = args.get_or("model", "resnet18").to_string();
    let res = args.usize_or("res", default_res(&name))?;
    let g = build_named(&name, res, args)?;
    let threads = args.usize_or("threads", 4)?;
    let cpu = cpu_by_name(args.get_or("cpu", "a72"))
        .context("unknown --cpu (a53|a72|a57)")?;
    let mut table = Table::new(
        &format!("cost projection — {name}@{res} on {} ({threads} threads)", cpu.name),
        &["engine", "projected latency", "FPS"],
    );
    for (label, force) in [
        ("FP32", Some(EngineKind::Fp32)),
        ("INT8", Some(EngineKind::Int8)),
        ("DLRT mixed (per-QCfg)", None),
        ("DLRT all-2A2W", Some(EngineKind::Bitserial { w_bits: 2, a_bits: 2 })),
        ("DLRT all-1A1W", Some(EngineKind::Bitserial { w_bits: 1, a_bits: 1 })),
    ] {
        let lat = costmodel::graph_latency_ms(&g, cpu, force, threads)?;
        table.row(vec![label.to_string(), ms(lat), format!("{:.1}", 1000.0 / lat)]);
    }
    if name.starts_with("resnet") {
        let gpu = costmodel::gpu_latency_ms(&g, &costmodel::JETSON_NANO_GPU)?;
        table.row(vec!["Jetson Nano GPU (ref)".into(), ms(gpu),
                       format!("{:.1}", 1000.0 / gpu)]);
    }
    table.print();
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let engine = EngineChoice::parse(args.get_or("engine", "auto"))?;
    let (name, model) = load_model(args, engine)?;
    let requests = args.usize_or("requests", 32)?;
    let cfg = ServerConfig {
        workers: args.usize_or("workers", 1)?,
        max_batch: args.usize_or("max-batch", 4)?,
        max_wait: std::time::Duration::from_millis(args.usize_or("max-wait-ms", 2)? as u64),
        threads_per_worker: args.usize_or("threads", 1)?,
    };
    let model = Arc::new(model);
    println!("serving {name} with {cfg:?}; {requests} synthetic requests");
    let server = InferenceServer::start(model.clone(), cfg);
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..requests)
        .map(|i| server.submit(random_input(&model, 1, i as u64)))
        .collect();
    for rx in rxs {
        rx.recv().expect("server alive")?;
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = server.metrics();
    println!("completed : {}", m.completed);
    println!("throughput: {:.2} req/s (wall {:.2}s)", requests as f64 / wall, wall);
    println!("exec p50  : {}", ms(m.p50_exec_ms));
    println!("exec p95  : {}", ms(m.p95_exec_ms));
    println!("queue p50 : {}", ms(m.p50_queue_ms));
    println!("mean batch: {:.2}", m.mean_batch);
    server.shutdown();
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_pjrt(_args: &Args) -> Result<()> {
    bail!("this binary was built without the `pjrt` feature; \
           rebuild with `cargo build --features pjrt` (needs the xla crate)")
}

#[cfg(feature = "pjrt")]
fn cmd_pjrt(args: &Args) -> Result<()> {
    let stem = args.positional.first().context("usage: dlrt pjrt <artifact_stem>")?;
    let rt = dlrt::runtime::PjrtRuntime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let model = rt.load_hlo(Path::new(stem))?;
    println!("loaded {} ({} params)", model.name, model.manifest.params.len());
    if !model.manifest.input_shape.is_empty() {
        // feed random params + input per the manifest
        let mut rng = Rng::new(0);
        let mut inputs: Vec<Tensor> = Vec::new();
        for (_, shape) in &model.manifest.params {
            let n: usize = shape.iter().product::<usize>().max(1);
            inputs.push(Tensor::new(shape.clone(),
                                    (0..n).map(|_| rng.normal() * 0.05).collect())?);
        }
        inputs.push({
            let s = &model.manifest.input_shape;
            let mut t = Tensor::zeros(s.clone());
            for v in t.data.iter_mut() {
                *v = rng.f32();
            }
            t
        });
        let t0 = std::time::Instant::now();
        let outs = model.run_f32(&inputs)?;
        println!("executed in {:.2} ms; {} outputs", t0.elapsed().as_secs_f64() * 1e3,
                 outs.len());
        for (i, o) in outs.iter().enumerate().take(4) {
            println!("  out{i}: {:?}", o.shape);
        }
    }
    Ok(())
}

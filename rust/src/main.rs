//! `dlrt` — DeepliteRT command-line interface.
//!
//! ```text
//! dlrt compile <model_dir> --out <file.dlrt> [--engine auto|fp32|int8]
//!              [--tune-db tune.json]   # consult a dlrt-tune DB and embed it
//! dlrt tune    [<model_dir> | --model NAME --res N] [--budget N] [--reps N]
//!              [--threads N] [--out tune.json] [--synthetic]
//!              # on-device schedule search for this host's selected ISA;
//!              # merges measured winners into the --out DB (re-run after
//!              # any kernel change — entries are benchmarks, not proofs);
//!              # --synthetic skips the search and writes deterministic
//!              # coverage schedules (CI / test fixture)
//! dlrt run     <file.dlrt | model_dir> [--threads N] [--reps N] [--batch B]
//! dlrt inspect [<file.dlrt | model_dir>] [--model NAME --res N] [--layers]
//!              [--plan]                  # dump the lowered execution plan
//!              [--json]                  # machine-readable plan + dispatch
//! dlrt profile <builder | file.dlrt | model_dir> [--reps N] [--threads N]
//!              [--batch B] [--res N] [--cpu a53|a72|a57] [--engine ...]
//!              [--out profile.json] [--trace trace.json]
//!              # per-instruction wall times + cost-model calibration
//! dlrt verify  [<file.dlrt | model_dir>] [--model NAME --res N]
//!              # run the static plan verifier and print its evidence counters
//! dlrt bench   [--model resnet18|resnet50|vgg16_ssd|yolov5n|s|m]
//!              [--res N] [--engine auto|fp32|int8] [--threads N] [--reps N]
//! dlrt cost    [--model ...] [--res N] [--cpu a53|a72|a57] [--threads N]
//! dlrt serve   --models spec[,spec...] [--listen ADDR] [--workers W]
//!              [--max-batch B] [--max-wait-ms MS] [--threads N]
//!              [--queue-cap Q] [--mem-budget-mb MB] [--replicas R]
//!              [--pin-cores] [--event-loops E] [--max-connections C]
//!              # spec: [name=]file.dlrt | [name=]model_dir | [name=]builder[@res]
//!              #       each spec takes ;key=value coordinator overrides,
//!              #       e.g. det=yolov5n@320;replicas=2;pin_cores=true
//!              # HTTP: GET /healthz /metrics /v1/models
//!              #       POST /v1/models/{name}/infer|load|unload
//!              #       POST /v1/admin/shutdown (graceful drain)
//! dlrt client  [--addr HOST:PORT] [--model NAME] [--requests N]
//!              [--concurrency C] [--rate RPS] [--json]   # loadgen
//!              [--conns K]               # keep-alive sockets (0 = per sender)
//!              [--out summary.json]      # machine-readable run summary
//! dlrt pjrt    <artifact_stem>        # run a JAX-AOT HLO artifact
//! ```

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use dlrt::bench_harness::{bench_ms, ms, reps_for, Table};
use dlrt::compiler::{compile_graph, load_arch, EngineChoice};
use dlrt::coordinator::ServerConfig;
use dlrt::costmodel::{self, cpu_by_name, EngineKind};
use dlrt::dlrt::format;
use dlrt::exec::Executor;
use dlrt::models;
use dlrt::serve::registry::{ModelRegistry, ModelSpec};
use dlrt::serve::{loadgen, Gateway, GatewayConfig};
use dlrt::util::cli::Args;
use dlrt::util::json::{arr, num, obj, s, Json};
use dlrt::util::rng::Rng;
use dlrt::Tensor;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print_usage();
        std::process::exit(2);
    }
    let cmd = argv[0].clone();
    let args = match Args::parse(argv[1..].to_vec()) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let result = match cmd.as_str() {
        "compile" => cmd_compile(&args),
        "tune" => cmd_tune(&args),
        "run" => cmd_run(&args),
        "inspect" => cmd_inspect(&args),
        "profile" => cmd_profile(&args),
        "verify" => cmd_verify(&args),
        "bench" => cmd_bench(&args),
        "cost" => cmd_cost(&args),
        "serve" => cmd_serve(&args),
        "client" => cmd_client(&args),
        "pjrt" => cmd_pjrt(&args),
        "help" | "--help" => {
            print_usage();
            Ok(())
        }
        other => {
            eprintln!("unknown command {other:?}");
            print_usage();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_usage() {
    eprintln!("dlrt — ultra-low-bit bitserial inference runtime (DeepliteRT repro)");
    eprintln!(
        "commands: compile | tune | run | inspect | profile | verify | bench | cost | \
         serve | client | pjrt"
    );
    eprintln!("see rust/src/main.rs docs or README.md for flags");
}

/// Build a model either from an exported dir/.dlrt or a native builder name.
fn load_model(args: &Args, engine: EngineChoice) -> Result<(String, dlrt::exec::CompiledModel)> {
    if let Some(path) = args.positional.first() {
        let p = Path::new(path);
        if p.extension().map(|e| e == "dlrt").unwrap_or(false) {
            return Ok((path.clone(), format::load(p)?));
        }
        let g = load_arch(p)?;
        return Ok((g.name.clone(), compile_graph(&g, engine)?));
    }
    let name = args.get_or("model", "resnet18").to_string();
    let res = args.usize_or("res", default_res(&name))?;
    let g = build_named(&name, res, args)?;
    Ok((format!("{name}@{res}"), compile_graph(&g, engine)?))
}

/// Like [`load_model`], but a positional that names nothing on disk is
/// treated as a builder name (`dlrt profile resnet18`).
fn load_model_flex(
    args: &Args,
    engine: EngineChoice,
) -> Result<(String, dlrt::exec::CompiledModel)> {
    if let Some(p) = args.positional.first() {
        if !Path::new(p).exists() {
            let name = p.clone();
            let res = args.usize_or("res", default_res(&name))?;
            let g = build_named(&name, res, args)?;
            return Ok((format!("{name}@{res}"), compile_graph(&g, engine)?));
        }
    }
    load_model(args, engine)
}

fn default_res(model: &str) -> usize {
    models::default_res(model)
}

fn build_named(name: &str, res: usize, args: &Args) -> Result<dlrt::Graph> {
    let wb = args.usize_or("w-bits", 2)? as u8;
    let ab = args.usize_or("a-bits", 2)? as u8;
    let wm = args.f64_or("width-mult", 1.0)? as f32;
    models::build_named(name, res, wb, ab, wm)
}

fn random_input(model: &dlrt::exec::CompiledModel, batch: usize, seed: u64) -> Tensor {
    let s = model.graph.input_shape;
    let mut rng = Rng::new(seed);
    let mut t = Tensor::zeros(vec![batch, s[1], s[2], s[3]]);
    for v in t.data.iter_mut() {
        *v = rng.f32();
    }
    t
}

fn cmd_compile(args: &Args) -> Result<()> {
    // accepts an exported model dir positionally, or a native builder via
    // --model NAME --res N (so CI can roundtrip a .dlrt without artifacts)
    let engine = EngineChoice::parse(args.get_or("engine", "auto"))?;
    let tune_db = match args.get("tune-db") {
        Some(p) => Some(dlrt::tune::TuningDb::load(Path::new(p))?),
        None => None,
    };
    let (name, model) = match &tune_db {
        Some(db) => {
            // compile against the explicit DB (load_model would only see
            // the DLRT_TUNE_DB ambient one)
            let isa = dlrt::kernels::ukernel::selected_isa().map_err(anyhow::Error::msg)?;
            let (name, g) = if let Some(path) = args.positional.first() {
                if Path::new(path).extension().map(|e| e == "dlrt").unwrap_or(false) {
                    bail!("--tune-db applies at compile time; pass a model dir or builder, \
                           not an already-compiled .dlrt");
                }
                let g = load_arch(Path::new(path))?;
                (g.name.clone(), g)
            } else {
                let name = args.get_or("model", "resnet18").to_string();
                let res = args.usize_or("res", default_res(&name))?;
                (format!("{name}@{res}"), build_named(&name, res, args)?)
            };
            (name, dlrt::compiler::compile_graph_tuned(&g, engine, isa, Some(db))?)
        }
        None => load_model(args, engine)?,
    };
    let out = PathBuf::from(args.get_or("out", "model.dlrt"));
    match &tune_db {
        Some(db) => format::save_with(&model, Some(db), &out)?,
        None => format::save(&model, &out)?,
    }
    let fp32_bytes: usize = model.graph.weights.values().map(|w| w.w.len() * 4).sum();
    println!("compiled {name} -> {}", out.display());
    println!("engines: {:?}", model.engine_summary());
    if tune_db.is_some() {
        let tuned = model.convs.iter().filter(|c| c.sched.is_some()).count();
        println!("tuned  : {tuned}/{} convs scheduled from the DB", model.convs.len());
    }
    if fp32_bytes > 0 {
        println!(
            "weights: {} B packed vs {} B fp32 ({:.2}x compression)",
            model.weight_bytes(),
            fp32_bytes,
            fp32_bytes as f64 / model.weight_bytes() as f64
        );
    } else {
        println!("weights: {} B packed", model.weight_bytes());
    }
    Ok(())
}

/// `dlrt tune` — benchmark candidate kernel schedules per (conv GEMM shape,
/// engine) on *this* machine and persist measured winners to a tuning DB.
/// The cost model only ranks the candidate grid (search prior); every
/// persisted entry won a wall-clock measurement by ≥2%, and lookups that
/// miss fall back to static defaults, so tuned plans are never slower by
/// construction.
fn cmd_tune(args: &Args) -> Result<()> {
    let isa = dlrt::kernels::ukernel::selected_isa().map_err(anyhow::Error::msg)?;
    let g = if let Some(path) = args.positional.first() {
        load_arch(Path::new(path))?
    } else {
        let name = args.get_or("model", "resnet18").to_string();
        let res = args.usize_or("res", default_res(&name))?;
        build_named(&name, res, args)?
    };
    let opts = dlrt::tune::TuneOpts {
        budget: args.usize_or("budget", 8)?,
        reps: args.usize_or("reps", 5)?,
        threads: args.usize_or("threads", 1)?,
    };
    let out = PathBuf::from(args.get_or("out", "tune.json"));
    // merge into an existing DB so successive runs accumulate shapes
    let mut db = if out.exists() {
        dlrt::tune::TuningDb::load(&out)?
    } else {
        dlrt::tune::TuningDb::new()
    };
    if args.flag("synthetic") {
        // deterministic coverage DB (CI / tests): no measurement, every
        // conv GEMM shape gets a synthetic schedule for each engine
        let syn = dlrt::tune::synthetic_db(&g, isa)?;
        let n = syn.entries.len();
        for e in syn.entries {
            db.upsert(e);
        }
        db.save(&out)?;
        println!("dlrt tune — {} on isa {}: synthetic coverage DB (no search)",
                 g.name, isa.name());
        println!("wrote {n} synthetic entries -> {} ({} entries total)",
                 out.display(), db.entries.len());
        println!("apply with: dlrt compile --tune-db {} | DLRT_TUNE_DB={}",
                 out.display(), out.display());
        return Ok(());
    }
    println!("dlrt tune — {} on isa {} (budget {}, reps {}, threads {})",
             g.name, isa.name(), opts.budget, opts.reps, opts.threads);
    let reports = dlrt::tune::tune_graph(&g, isa, &opts, &mut db)?;
    let mut table = Table::new(
        &format!("schedule search — {} @ {}", g.name, isa.name()),
        &["shape MxKxN", "convs", "engine", "default", "tuned", "schedule", "result"],
    );
    for r in &reports {
        table.row(vec![
            format!("{}x{}x{}", r.m, r.k, r.n),
            r.convs.to_string(),
            r.engine.clone(),
            ms(r.default_ms),
            ms(r.tuned_ms),
            format!("{}x{} u{} t{} {}", r.sched.tile_m, r.sched.tile_n, r.sched.k_unroll,
                    r.sched.threads, r.sched.staging.name()),
            if r.improved {
                format!("{:.2}x", r.default_ms / r.tuned_ms.max(1e-9))
            } else {
                "default kept".to_string()
            },
        ]);
    }
    table.print();
    db.save(&out)?;
    let kept = reports.iter().filter(|r| r.improved).count();
    println!("kept {kept}/{} searched schedules -> {} ({} entries total)",
             reports.len(), out.display(), db.entries.len());
    println!("apply with: dlrt compile --tune-db {} | DLRT_TUNE_DB={}",
             out.display(), out.display());
    Ok(())
}

fn cmd_verify(args: &Args) -> Result<()> {
    let engine = EngineChoice::parse(args.get_or("engine", "auto"))?;
    let (name, model) = load_model(args, engine)?;
    match dlrt::exec::verify::verify(&model.plan) {
        Ok(rep) => {
            println!("{name}: plan OK");
            println!(
                "verified {} instrs over {} slots: {} regions, {} kills, {} reads checked, \
                 {} race partitions proven disjoint",
                rep.instrs, rep.slots, rep.regions, rep.kills, rep.reads, rep.race_checks
            );
            Ok(())
        }
        Err(d) => {
            println!("{name}: plan REJECTED");
            println!("{d}");
            bail!("plan verification failed for {name}")
        }
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let engine = EngineChoice::parse(args.get_or("engine", "auto"))?;
    let (name, model) = load_model(args, engine)?;
    let threads = args.usize_or("threads", 1)?;
    let batch = args.usize_or("batch", 1)?;
    let mut ex = Executor::new(threads);
    let x = random_input(&model, batch, 1);
    let outs = ex.run(&model, &x)?;
    let t0 = std::time::Instant::now();
    ex.run(&model, &x)?;
    let first_ms = t0.elapsed().as_secs_f64() * 1e3;
    let reps = args.usize_or("reps", reps_for(first_ms, 2000.0))?;
    let timing = bench_ms(1, reps, || {
        ex.run(&model, &x).unwrap();
    });
    println!("model   : {name}");
    println!("engines : {:?}", model.engine_summary());
    println!("input   : {:?}", x.shape);
    for (i, o) in outs.iter().enumerate() {
        let mn = o.data.iter().cloned().fold(f32::INFINITY, f32::min);
        let mx = o.data.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        println!("output{} : {:?} range [{mn:.4}, {mx:.4}]", i, o.shape);
    }
    println!("latency : {} (median of {}, ±{})", ms(timing.median_ms), timing.reps,
             ms(timing.mad_ms));
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    // accepts a .dlrt file / model dir positionally, or a native builder
    // via --model NAME --res N (so CI can exercise plan lowering without
    // exported artifacts)
    let engine = EngineChoice::parse(args.get_or("engine", "auto"))?;
    let (_source, model) = load_model(args, engine)?;
    let g = &model.graph;
    let peak = dlrt::exec::planner::peak_live_elems(g)?;
    if args.flag("json") {
        println!("{}", inspect_json(&model, peak).to_string());
        return Ok(());
    }
    println!("model   : {}", g.name);
    println!("input   : {} {:?}", g.input_name, g.input_shape);
    println!("nodes   : {} ({} convs)", g.nodes.len(), g.conv_nodes().count());
    println!("outputs : {:?}", g.outputs);
    println!("engines : {:?}", model.engine_summary());
    println!("weights : {} bytes", model.weight_bytes());
    println!("peak act: {peak} f32 elems");
    if args.flag("layers") {
        for c in &model.convs {
            println!("  {:<24} {:<9} scale[{}]", c.name, c.kernel.engine_name(),
                     c.scale.len());
        }
    }
    if args.flag("plan") {
        let p = &model.plan;
        // which micro-kernel the compile-time ISA dispatch resolved to
        let desc = dlrt::kernels::ukernel::kernel_for(model.isa).map(|u| u.desc);
        if let Some(d) = desc {
            println!(
                "ukernel : isa={} tile {}x{} k-unroll {}",
                d.isa.name(),
                d.tile_m,
                d.tile_n,
                d.k_unroll
            );
        }
        let vectorized =
            if model.isa == dlrt::kernels::ukernel::Isa::Scalar { 0 } else { p.conv_kernels };
        println!(
            "dispatch: isa={}, {}/{} convs vectorized",
            model.isa.name(),
            vectorized,
            p.conv_kernels
        );
        println!(
            "plan    : {} instrs ({} fused epilogues, {} in-place), {} slots",
            p.instrs.len(),
            p.fused_instrs(),
            p.in_place_instrs(),
            p.slot_sizes.len()
        );
        // greppable one-per-line counters (CI asserts on these)
        println!("fused residual adds : {}", p.fused_add_instrs());
        println!("in-place concats    : {}", p.in_place_concats);
        println!("partial concats     : {}", p.partial_concats);
        println!("striped writers     : {}", p.strided_instrs());
        println!("stripe readers      : {}", p.read_view_instrs());
        println!("same-slot stripes   : {}", p.same_slot_stripe_instrs());
        println!("concat copy instrs  : {}", p.concat_copy_instrs());
        let tuned = model.convs.iter().filter(|c| c.sched.is_some()).count();
        println!("tuned schedules     : {tuned}/{} convs", model.convs.len());
        match dlrt::exec::verify::verify(p) {
            Ok(rep) => println!(
                "verifier: OK — {} regions, {} kills, {} reads, {} race partitions",
                rep.regions, rep.kills, rep.reads, rep.race_checks
            ),
            Err(d) => println!("verifier: REJECTED — {d}"),
        }
        println!(
            "arena   : {} f32 elems ({} bytes) @ batch {} — interpreter peak {} ({} bytes)",
            p.arena_elems(p.nominal_batch),
            4 * p.arena_elems(p.nominal_batch),
            p.nominal_batch,
            peak,
            4 * peak
        );
        for fb in &p.concat_fallbacks {
            println!("concat fallback: {fb}");
        }
        for (i, ins) in p.instrs.iter().enumerate() {
            let mut fused = match ins.fused {
                Some(a) => format!(" +{}", a.name()),
                None => String::new(),
            };
            if ins.fused_add {
                fused.push_str(" +add");
            }
            if let Some(a) = ins.fused_post {
                fused.push_str(&format!(" +{}", a.name()));
            }
            let mode = if ins.in_place { " (in-place)" } else { "" };
            let kern = match (ins.kernel_idx, desc) {
                (Some(ki), Some(d)) => {
                    let (eng, sched) = match &ins.op {
                        dlrt::dlrt::graph::Op::Conv2d { .. } => {
                            let c = model.convs.get(ki);
                            (c.map(|c| c.kernel.engine_name()).unwrap_or("?"),
                             c.and_then(|c| c.sched))
                        }
                        _ => ("dense", None),
                    };
                    // a tuned schedule overrides the default tile geometry
                    let (tm, tn, tag) = match sched {
                        Some(sc) => (sc.tile_m, sc.tile_n, " tuned"),
                        None => (d.tile_m, d.tile_n, ""),
                    };
                    format!(" uk#{ki}[{eng} {} {tm}x{tn}{tag}]", d.isa.name())
                }
                _ => String::new(),
            };
            let mut stripe = match ins.out_view {
                Some(v) => format!(" stripe[{}..{}/{}]", v.off,
                                   v.off + ins.out_tail.last().copied().unwrap_or(0),
                                   v.stride),
                None => String::new(),
            };
            for (k, iv) in ins.in_views.iter().enumerate() {
                if let Some(v) = iv {
                    stripe.push_str(&format!(
                        " read{k}[{}..{}/{}]",
                        v.off,
                        v.off + ins.in_tails[k].last().copied().unwrap_or(0),
                        v.stride
                    ));
                }
            }
            println!(
                "  {i:>3}: {:<12} {:<24} in={:?} out={} {:?}{fused}{stripe}{mode}{kern}",
                ins.op.name(),
                ins.name,
                ins.in_slots,
                ins.out_slot,
                ins.out_tail
            );
        }
    }
    Ok(())
}

/// `dlrt inspect --json`: the plan + dispatch summary as one JSON doc.
fn inspect_json(model: &dlrt::exec::CompiledModel, peak: usize) -> Json {
    let g = &model.graph;
    let p = &model.plan;
    let desc = dlrt::kernels::ukernel::kernel_for(model.isa).map(|u| u.desc);
    let instrs: Vec<Json> = p
        .instr_meta()
        .iter()
        .enumerate()
        .map(|(i, m)| {
            obj(vec![
                ("index", num(i as f64)),
                ("name", s(&m.name)),
                ("op", s(m.op)),
                ("class", s(dlrt::obs::OP_CLASSES[m.class])),
                ("kernel_idx", m.kernel_idx.map(|k| num(k as f64)).unwrap_or(Json::Null)),
                ("out_slot", num(m.out_slot as f64)),
                ("flops", num(m.flops as f64)),
                ("bytes", num(m.bytes as f64)),
                ("fused", s(m.fused.trim())),
                ("strided", Json::Bool(m.strided)),
                ("in_place", Json::Bool(m.in_place)),
            ])
        })
        .collect();
    let mut pairs = vec![
        ("model", s(&g.name)),
        ("input", arr(g.input_shape.iter().map(|&d| num(d as f64)).collect())),
        ("nodes", num(g.nodes.len() as f64)),
        ("convs", num(g.conv_nodes().count() as f64)),
        ("weight_bytes", num(model.weight_bytes() as f64)),
        ("peak_act_elems", num(peak as f64)),
        ("isa", s(model.isa.name())),
        ("tuned_convs",
         num(model.convs.iter().filter(|c| c.sched.is_some()).count() as f64)),
        (
            "plan",
            obj(vec![
                ("instrs", num(p.instrs.len() as f64)),
                ("slots", num(p.slot_sizes.len() as f64)),
                ("conv_kernels", num(p.conv_kernels as f64)),
                ("fused_epilogues", num(p.fused_instrs() as f64)),
                ("fused_residual_adds", num(p.fused_add_instrs() as f64)),
                ("in_place", num(p.in_place_instrs() as f64)),
                ("in_place_concats", num(p.in_place_concats as f64)),
                ("partial_concats", num(p.partial_concats as f64)),
                ("striped_writers", num(p.strided_instrs() as f64)),
                ("stripe_readers", num(p.read_view_instrs() as f64)),
                ("same_slot_stripes", num(p.same_slot_stripe_instrs() as f64)),
                ("concat_copy_instrs", num(p.concat_copy_instrs() as f64)),
                ("arena_elems", num(p.arena_elems(p.nominal_batch) as f64)),
                ("nominal_batch", num(p.nominal_batch as f64)),
            ]),
        ),
        ("instructions", arr(instrs)),
    ];
    if let Some(d) = desc {
        pairs.push((
            "ukernel",
            obj(vec![
                ("isa", s(d.isa.name())),
                ("tile_m", num(d.tile_m as f64)),
                ("tile_n", num(d.tile_n as f64)),
                ("k_unroll", num(d.k_unroll as f64)),
            ]),
        ));
    }
    obj(pairs)
}

/// Map a compiled conv kernel to the cost model's engine taxonomy.
fn conv_engine_kind(kernel: &dlrt::exec::ConvKernel) -> EngineKind {
    match kernel {
        dlrt::exec::ConvKernel::Bitserial { w_bits, a_bits, .. } => {
            EngineKind::Bitserial { w_bits: *w_bits, a_bits: *a_bits }
        }
        dlrt::exec::ConvKernel::Fp32 { .. } => EngineKind::Fp32,
        dlrt::exec::ConvKernel::Int8 { .. } => EngineKind::Int8,
    }
}

fn cmd_profile(args: &Args) -> Result<()> {
    use dlrt::dlrt::graph::Op;

    let engine = EngineChoice::parse(args.get_or("engine", "auto"))?;
    let (name, model) = load_model_flex(args, engine)?;
    let threads = args.usize_or("threads", 1)?;
    let batch = args.usize_or("batch", 1)?;
    let reps = args.usize_or("reps", 10)?.max(1);
    let cpu = cpu_by_name(args.get_or("cpu", "a72"))
        .context("unknown --cpu (a53|a72|a57)")?;

    let mut ex = Executor::new(threads);
    let x = random_input(&model, batch, 1);
    ex.run(&model, &x)?; // warm: arena + scratch allocated before profiling
    ex.enable_profiling(&model.plan);
    for _ in 0..reps {
        ex.run(&model, &x)?;
    }
    let meta = model.plan.instr_meta();
    let prof = ex.profiler().expect("profiling just enabled");
    let runs = prof.runs().max(1) as f64;
    let sum_s = prof.sum_total_s();
    let wall_s = prof.run_total_s();
    let desc = dlrt::kernels::ukernel::kernel_for(model.isa).map(|u| u.desc);

    let mut table = Table::new(
        &format!("dlrt profile — {name} (batch {batch}, {threads} threads, {reps} reps, \
                  isa {})", model.isa.name()),
        &["#", "op", "name", "kernel", "mean", "p95", "GFLOP/s", "% total"],
    );
    for (i, m) in meta.iter().enumerate() {
        let st = prof.stats(i);
        let kern = match (m.kernel_idx, desc) {
            (Some(ki), Some(d)) => {
                let eng = if m.op == "conv2d" {
                    model.convs.get(ki).map(|c| c.kernel.engine_name()).unwrap_or("?")
                } else {
                    "dense"
                };
                format!("uk#{ki}[{eng} {} {}x{}]", d.isa.name(), d.tile_m, d.tile_n)
            }
            _ => String::new(),
        };
        let gflops = if st.mean_s > 0.0 {
            (m.flops * batch as u64) as f64 / st.mean_s / 1e9
        } else {
            0.0
        };
        let share = if sum_s > 0.0 { 100.0 * prof.instr_total_s(i) / sum_s } else { 0.0 };
        table.row(vec![
            i.to_string(),
            format!("{}{}", m.op, m.fused),
            m.name.clone(),
            kern,
            ms(st.mean_s * 1e3),
            ms(st.p95_s * 1e3),
            format!("{gflops:.2}"),
            format!("{share:.1}%"),
        ]);
    }
    table.print();
    let covered = if wall_s > 0.0 { 100.0 * sum_s / wall_s } else { 0.0 };
    println!(
        "instr sum {} vs end-to-end {} over {} runs ({covered:.1}% covered)",
        ms(sum_s * 1e3),
        ms(wall_s * 1e3),
        prof.runs()
    );

    // Predicted vs measured per kernel-table entry: the cost model prices
    // each conv/dense GEMM for the target CPU; "measured" is this host's
    // mean per-run wall time, so the ratio calibrates model vs reality.
    println!();
    let mut cal = Table::new(
        &format!("cost-model calibration — target {} ({threads} threads)", cpu.name),
        &["instr", "kernel", "engine", "predicted", "measured", "meas/pred"],
    );
    let mut cal_json: Vec<Json> = Vec::new();
    for (i, ins) in model.plan.instrs.iter().enumerate() {
        let Some(ki) = ins.kernel_idx else { continue };
        let measured_s = prof.instr_total_s(i) / runs;
        let (kind, pred_s) = match &ins.op {
            Op::Conv2d { kernel, cin, cout, .. } => {
                let Some(conv) = model.convs.get(ki) else { continue };
                let pixels: usize = ins.out_tail[..ins.out_tail.len() - 1].iter().product();
                let rows = batch * pixels;
                let k = kernel[0] * kernel[1] * cin;
                let kind = conv_engine_kind(&conv.kernel);
                (kind, costmodel::conv_cost_s(cpu, rows, k, *cout, kind, threads))
            }
            Op::Dense { cin, cout } => {
                let kind = EngineKind::Fp32;
                (kind, costmodel::conv_cost_s(cpu, batch, *cin, *cout, kind, threads))
            }
            _ => continue,
        };
        let ratio = if pred_s > 0.0 { measured_s / pred_s } else { 0.0 };
        cal.row(vec![
            ins.name.clone(),
            format!("uk#{ki}"),
            kind.label(),
            ms(pred_s * 1e3),
            ms(measured_s * 1e3),
            format!("{ratio:.2}x"),
        ]);
        cal_json.push(obj(vec![
            ("instr", s(&ins.name)),
            ("kernel_idx", num(ki as f64)),
            ("engine", Json::Str(kind.label())),
            ("predicted_ms", num(pred_s * 1e3)),
            ("measured_ms", num(measured_s * 1e3)),
            ("ratio", num(ratio)),
        ]));
    }
    cal.print();

    if let Some(path) = args.get("out") {
        let instrs: Vec<Json> = meta
            .iter()
            .enumerate()
            .map(|(i, m)| {
                let st = prof.stats(i);
                obj(vec![
                    ("index", num(i as f64)),
                    ("name", s(&m.name)),
                    ("op", s(m.op)),
                    ("class", s(dlrt::obs::OP_CLASSES[m.class])),
                    ("fused", s(m.fused.trim())),
                    ("kernel_idx", m.kernel_idx.map(|k| num(k as f64)).unwrap_or(Json::Null)),
                    ("mean_ms", num(st.mean_s * 1e3)),
                    ("p95_ms", num(st.p95_s * 1e3)),
                    ("total_ms", num(prof.instr_total_s(i) * 1e3)),
                    ("flops", num((m.flops * batch as u64) as f64)),
                ])
            })
            .collect();
        let doc = obj(vec![
            ("model", s(&name)),
            ("isa", s(model.isa.name())),
            ("batch", num(batch as f64)),
            ("threads", num(threads as f64)),
            ("reps", num(reps as f64)),
            ("wall_ms", num(wall_s * 1e3)),
            ("instr_sum_ms", num(sum_s * 1e3)),
            ("target_cpu", s(cpu.name)),
            ("instructions", arr(instrs)),
            ("calibration", arr(cal_json)),
        ]);
        std::fs::write(path, doc.to_string()).with_context(|| format!("writing {path}"))?;
        println!("wrote profile JSON -> {path}");
    }

    if let Some(path) = args.get("trace") {
        let doc = dlrt::obs::trace::profile_trace_json(&meta, prof);
        std::fs::write(path, doc.to_string()).with_context(|| format!("writing {path}"))?;
        println!("wrote Chrome trace -> {path} (load in ui.perfetto.dev)");
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let engine = EngineChoice::parse(args.get_or("engine", "auto"))?;
    let (name, model) = load_model(args, engine)?;
    let threads = args.usize_or("threads", 1)?;
    let mut ex = Executor::new(threads);
    let x = random_input(&model, 1, 1);
    ex.run(&model, &x)?; // warm
    let t0 = std::time::Instant::now();
    ex.run(&model, &x)?;
    let first = t0.elapsed().as_secs_f64() * 1e3;
    let reps = args.usize_or("reps", reps_for(first, 5000.0))?;
    let timing = bench_ms(0, reps, || {
        ex.run(&model, &x).unwrap();
    });
    let mut table = Table::new(&format!("dlrt bench — {name}"),
                               &["engine", "threads", "median", "MAD", "reps"]);
    table.row(vec![
        format!("{:?}", model.engine_summary()),
        threads.to_string(),
        ms(timing.median_ms),
        ms(timing.mad_ms),
        timing.reps.to_string(),
    ]);
    table.print();
    Ok(())
}

fn cmd_cost(args: &Args) -> Result<()> {
    let name = args.get_or("model", "resnet18").to_string();
    let res = args.usize_or("res", default_res(&name))?;
    let g = build_named(&name, res, args)?;
    let threads = args.usize_or("threads", 4)?;
    let cpu = cpu_by_name(args.get_or("cpu", "a72"))
        .context("unknown --cpu (a53|a72|a57)")?;
    let mut table = Table::new(
        &format!("cost projection — {name}@{res} on {} ({threads} threads)", cpu.name),
        &["engine", "projected latency", "FPS"],
    );
    for (label, force) in [
        ("FP32", Some(EngineKind::Fp32)),
        ("INT8", Some(EngineKind::Int8)),
        ("DLRT mixed (per-QCfg)", None),
        ("DLRT all-2A2W", Some(EngineKind::Bitserial { w_bits: 2, a_bits: 2 })),
        ("DLRT all-1A1W", Some(EngineKind::Bitserial { w_bits: 1, a_bits: 1 })),
    ] {
        let lat = costmodel::graph_latency_ms(&g, cpu, force, threads)?;
        table.row(vec![label.to_string(), ms(lat), format!("{:.1}", 1000.0 / lat)]);
    }
    if name.starts_with("resnet") {
        let gpu = costmodel::gpu_latency_ms(&g, &costmodel::JETSON_NANO_GPU)?;
        table.row(vec!["Jetson Nano GPU (ref)".into(), ms(gpu),
                       format!("{:.1}", 1000.0 / gpu)]);
    }
    table.print();
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let listen = args.get_or("listen", "127.0.0.1:8080");
    let specs = args
        .require("models")
        .context("usage: dlrt serve --listen ADDR --models spec[,spec...]")?;
    let mem_budget_bytes = args.usize_or("mem-budget-mb", 0)? * 1024 * 1024;
    // Queue bound precedence: explicit --queue-cap wins; otherwise a
    // memory budget derives the bound per model from the plan's footprint
    // (queue_cap 0 + budget triggers the derivation in the coordinator);
    // with neither, the gateway still bounds queues at 256.
    let queue_cap = match args.get("queue-cap") {
        Some(v) => v.parse().context("bad --queue-cap")?,
        None if mem_budget_bytes > 0 => 0,
        None => 256,
    };
    let base = ServerConfig {
        workers: args.usize_or("workers", 1)?,
        max_batch: args.usize_or("max-batch", 4)?,
        max_wait: std::time::Duration::from_millis(args.usize_or("max-wait-ms", 2)? as u64),
        threads_per_worker: args.usize_or("threads", 1)?,
        queue_cap,
        mem_budget_bytes,
        replicas: args.usize_or("replicas", 1)?,
        pin_cores: args.flag("pin-cores"),
    };
    let registry = Arc::new(ModelRegistry::new(base));
    for item in specs.split(',').filter(|s| !s.trim().is_empty()) {
        let spec = ModelSpec::parse(item)?;
        registry.load_spec(&spec)?;
        let entry = registry.get(&spec.name).expect("just loaded");
        let eff = entry.server.config();
        println!(
            "loaded {:<20} <- {} | workers {} max_batch {} queue_cap {} arena {} B/item",
            spec.name,
            entry.source,
            eff.workers,
            eff.max_batch,
            eff.queue_cap,
            entry.model.plan.arena_bytes(1),
        );
    }
    let gw_cfg = GatewayConfig {
        max_body_bytes: args.usize_or("max-body-mb", 64)? << 20,
        max_connections: args.usize_or("max-connections", 256)?,
        event_loops: args.usize_or("event-loops", 0)?,
        ..GatewayConfig::default()
    };
    let gateway = Gateway::bind(listen, registry, gw_cfg)?;
    println!("listening on http://{}", gateway.local_addr());
    println!(
        "endpoints: GET /healthz | GET /metrics | GET /v1/models | \
         POST /v1/models/{{name}}/infer|load|unload | POST /v1/admin/shutdown"
    );
    // Serve until a client POSTs /v1/admin/shutdown (graceful drain); a
    // signal kills the process without draining, so orchestrators should
    // hit the endpoint first.
    gateway.wait_shutdown_requested();
    println!("shutdown requested; draining in-flight connections and model queues ...");
    gateway.shutdown();
    println!("drained cleanly");
    Ok(())
}

fn cmd_client(args: &Args) -> Result<()> {
    let cfg = loadgen::LoadgenConfig {
        addr: args.get_or("addr", "127.0.0.1:8080").to_string(),
        model: args.get_or("model", "").to_string(),
        requests: args.usize_or("requests", 64)?,
        concurrency: args.usize_or("concurrency", 4)?,
        rate: args.f64_or("rate", 0.0)?,
        json: args.flag("json"),
        timeout: std::time::Duration::from_millis(args.usize_or("timeout-ms", 30_000)? as u64),
        conns: args.usize_or("conns", 0)?,
    };
    let mode = if cfg.rate > 0.0 {
        format!("open loop @ {:.1} req/s", cfg.rate)
    } else {
        "closed loop".to_string()
    };
    println!(
        "loadgen -> http://{} model {:?} ({} requests, {} senders, {mode})",
        cfg.addr,
        if cfg.model.is_empty() { "<first>" } else { cfg.model.as_str() },
        cfg.requests,
        cfg.concurrency
    );
    let rep = loadgen::run(&cfg)?;
    if let Some(path) = args.get("out") {
        std::fs::write(path, rep.to_json().to_string())
            .with_context(|| format!("writing {path}"))?;
        println!("wrote client summary -> {path}");
    }
    let mut table = Table::new(
        &format!("dlrt client — {}", rep.model),
        &["sent", "ok", "errors", "p50", "p95", "p99", "mean", "req/s"],
    );
    let errors: usize =
        rep.status_counts.values().sum::<usize>() + rep.transport_errors;
    table.row(vec![
        rep.sent.to_string(),
        rep.ok.to_string(),
        errors.to_string(),
        ms(rep.p50_ms),
        ms(rep.p95_ms),
        ms(rep.p99_ms),
        ms(rep.mean_ms),
        format!("{:.1}", rep.achieved_rps),
    ]);
    table.print();
    for (status, n) in &rep.status_counts {
        println!("  HTTP {status}: {n}");
    }
    if rep.transport_errors > 0 {
        println!("  transport errors: {}", rep.transport_errors);
    }
    if rep.ok < rep.sent {
        std::process::exit(1);
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_pjrt(_args: &Args) -> Result<()> {
    bail!("this binary was built without the `pjrt` feature; \
           rebuild with `cargo build --features pjrt` (needs the xla crate)")
}

#[cfg(feature = "pjrt")]
fn cmd_pjrt(args: &Args) -> Result<()> {
    let stem = args.positional.first().context("usage: dlrt pjrt <artifact_stem>")?;
    let rt = dlrt::runtime::PjrtRuntime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let model = rt.load_hlo(Path::new(stem))?;
    println!("loaded {} ({} params)", model.name, model.manifest.params.len());
    if !model.manifest.input_shape.is_empty() {
        // feed random params + input per the manifest
        let mut rng = Rng::new(0);
        let mut inputs: Vec<Tensor> = Vec::new();
        for (_, shape) in &model.manifest.params {
            let n: usize = shape.iter().product::<usize>().max(1);
            inputs.push(Tensor::new(shape.clone(),
                                    (0..n).map(|_| rng.normal() * 0.05).collect())?);
        }
        inputs.push({
            let s = &model.manifest.input_shape;
            let mut t = Tensor::zeros(s.clone());
            for v in t.data.iter_mut() {
                *v = rng.f32();
            }
            t
        });
        let t0 = std::time::Instant::now();
        let outs = model.run_f32(&inputs)?;
        println!("executed in {:.2} ms; {} outputs", t0.elapsed().as_secs_f64() * 1e3,
                 outs.len());
        for (i, o) in outs.iter().enumerate().take(4) {
            println!("  out{i}: {:?}", o.shape);
        }
    }
    Ok(())
}

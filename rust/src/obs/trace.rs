//! Request-scoped span records and Chrome trace-event JSON export.
//!
//! [`TraceBuffer`] is a bounded, preallocated ring of fixed-size
//! [`SpanRec`]s shared by all gateway connections — recording takes a
//! mutex but never allocates, so it is safe on the zero-steady-state-
//! allocation request path. Export ([`chrome_trace_json`],
//! [`profile_trace_json`]) renders the standard Chrome trace-event
//! format (`{"traceEvents": [...]}`), which loads directly in Perfetto
//! (ui.perfetto.dev) or `chrome://tracing`.

use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::{arr, num, obj, s, Json};

use super::{InstrMeta, InstrProfiler};

/// Gateway request lifecycle stages.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// Connection accepted (instant event, one per connection).
    Accept,
    /// Request body parsed into an input tensor.
    Parse,
    /// Time spent waiting in the coordinator queue.
    Queue,
    /// Batch assembly + plan execution window for the whole batch.
    Batch,
    /// This request's share of plan execution.
    Exec,
    /// Response rendering + write-back.
    Respond,
}

impl SpanKind {
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Accept => "accept",
            SpanKind::Parse => "parse",
            SpanKind::Queue => "queue-wait",
            SpanKind::Batch => "batch",
            SpanKind::Exec => "exec",
            SpanKind::Respond => "respond",
        }
    }
}

/// One fixed-size span record. Numeric request sequence instead of the
/// string request ID so recording never allocates; the access log ties
/// sequence numbers back to IDs.
#[derive(Clone, Copy, Debug)]
pub struct SpanRec {
    pub kind: SpanKind,
    /// Gateway-local request sequence number (trace `tid`).
    pub req: u64,
    /// Microseconds since the buffer's epoch.
    pub ts_us: u64,
    pub dur_us: u64,
    pub batch_index: u32,
    pub batch_size: u32,
    pub status: u16,
}

struct Ring {
    buf: Vec<SpanRec>,
    next: usize,
    /// Spans recorded over the buffer's lifetime (may exceed capacity).
    total: u64,
}

/// Bounded in-memory span ring (`GET /v1/debug/trace` serves a snapshot).
pub struct TraceBuffer {
    inner: Mutex<Ring>,
    cap: usize,
    epoch: Instant,
}

impl TraceBuffer {
    /// Preallocate space for `cap` spans; older spans are overwritten.
    pub fn with_capacity(cap: usize) -> TraceBuffer {
        TraceBuffer {
            inner: Mutex::new(Ring { buf: Vec::with_capacity(cap), next: 0, total: 0 }),
            cap: cap.max(1),
            epoch: Instant::now(),
        }
    }

    /// Microseconds since this buffer was created — the timebase for
    /// [`SpanRec::ts_us`].
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Record one span. Alloc-free: writes into preallocated capacity.
    pub fn record(&self, rec: SpanRec) {
        let mut r = self.inner.lock().unwrap();
        if r.buf.len() < self.cap {
            r.buf.push(rec); // within preallocated capacity
        } else {
            let i = r.next;
            r.buf[i] = rec;
        }
        r.next = (r.next + 1) % self.cap;
        r.total += 1;
    }

    /// Spans recorded over the buffer's lifetime.
    pub fn total(&self) -> u64 {
        self.inner.lock().unwrap().total
    }

    /// Copy out the retained spans in recording order (report-time;
    /// allocates).
    pub fn snapshot(&self) -> Vec<SpanRec> {
        let r = self.inner.lock().unwrap();
        if r.buf.len() < self.cap {
            r.buf.clone()
        } else {
            let mut out = Vec::with_capacity(self.cap);
            out.extend_from_slice(&r.buf[r.next..]);
            out.extend_from_slice(&r.buf[..r.next]);
            out
        }
    }
}

/// One Chrome trace event. `ph` is `"X"` (complete) when `dur_us > 0`,
/// `"i"` (instant) otherwise.
pub fn chrome_event(
    name: &str,
    cat: &str,
    tid: u64,
    ts_us: f64,
    dur_us: f64,
    args: Vec<(&str, Json)>,
) -> Json {
    let mut pairs = vec![
        ("name", s(name)),
        ("cat", s(cat)),
        ("ph", s(if dur_us > 0.0 { "X" } else { "i" })),
        ("pid", num(1.0)),
        ("tid", num(tid as f64)),
        ("ts", num(ts_us)),
    ];
    if dur_us > 0.0 {
        pairs.push(("dur", num(dur_us)));
    }
    pairs.push(("args", obj(args)));
    obj(pairs)
}

/// Render gateway spans as a Chrome trace document.
pub fn chrome_trace_json(spans: &[SpanRec]) -> Json {
    let events = spans
        .iter()
        .map(|rec| {
            chrome_event(
                rec.kind.name(),
                "gateway",
                rec.req,
                rec.ts_us as f64,
                rec.dur_us as f64,
                vec![
                    ("req", num(rec.req as f64)),
                    ("batch_index", num(rec.batch_index as f64)),
                    ("batch_size", num(rec.batch_size as f64)),
                    ("status", num(rec.status as f64)),
                ],
            )
        })
        .collect();
    obj(vec![("traceEvents", arr(events))])
}

/// Render the last profiled run as a Chrome trace document: one `exec`
/// span covering the whole plan plus one span per instruction, labelled
/// from the plan's static metadata. Used by `dlrt profile --trace`.
pub fn profile_trace_json(meta: &[InstrMeta], prof: &InstrProfiler) -> Json {
    let n = meta.len().min(prof.len());
    let mut events = Vec::with_capacity(n + 1);
    let mut end_s = 0.0f64;
    for i in 0..n {
        let (start_s, dur_s) = prof.last_span_s(i);
        end_s = end_s.max(start_s + dur_s);
        events.push(chrome_event(
            &meta[i].name,
            meta[i].op,
            0,
            start_s * 1e6,
            dur_s * 1e6,
            vec![
                ("op", s(meta[i].op)),
                ("out_slot", num(meta[i].out_slot as f64)),
                ("flops", num(meta[i].flops as f64)),
                ("bytes", num(meta[i].bytes as f64)),
            ],
        ));
    }
    // whole-run envelope span, named "exec" (CI greps for it)
    events.insert(
        0,
        chrome_event(
            "exec",
            "plan",
            0,
            0.0,
            end_s * 1e6,
            vec![("instrs", num(n as f64)), ("runs", num(prof.runs() as f64))],
        ),
    );
    obj(vec![("traceEvents", arr(events))])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(req: u64, ts: u64) -> SpanRec {
        SpanRec {
            kind: SpanKind::Exec,
            req,
            ts_us: ts,
            dur_us: 5,
            batch_index: 0,
            batch_size: 1,
            status: 200,
        }
    }

    #[test]
    fn ring_overwrites_oldest_and_keeps_order() {
        let tb = TraceBuffer::with_capacity(4);
        for i in 0..6u64 {
            tb.record(span(i, i * 10));
        }
        assert_eq!(tb.total(), 6);
        let snap = tb.snapshot();
        assert_eq!(snap.len(), 4);
        let reqs: Vec<u64> = snap.iter().map(|r| r.req).collect();
        assert_eq!(reqs, vec![2, 3, 4, 5]);
    }

    #[test]
    fn chrome_export_round_trips_as_json() {
        let tb = TraceBuffer::with_capacity(8);
        tb.record(span(1, 100));
        tb.record(SpanRec { kind: SpanKind::Accept, dur_us: 0, ..span(1, 90) });
        let doc = chrome_trace_json(&tb.snapshot());
        let parsed = Json::parse(&doc.to_string()).unwrap();
        let events = parsed.get("traceEvents").unwrap().arr().unwrap();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].get("name").unwrap().str().unwrap(), "exec");
        assert_eq!(events[0].get("ph").unwrap().str().unwrap(), "X");
        // zero-duration accept span exports as an instant event
        assert_eq!(events[1].get("ph").unwrap().str().unwrap(), "i");
    }
}

//! Observability: per-instruction profiling, request-scoped tracing, and
//! structured access logs.
//!
//! Everything here obeys the runtime's zero-steady-state-allocation rule:
//! rings and buffers are preallocated when instrumentation is enabled (at
//! plan/gateway build time), and the hot-path record calls
//! ([`InstrProfiler::record`], [`trace::TraceBuffer::record`]) only write
//! into that storage. Reporting (`stats`, trace export, Prometheus
//! rendering) is allowed to allocate — it runs off the request path.
//!
//! Layout:
//! * [`InstrProfiler`] — per-`Instr` wall-time rings owned by an
//!   `exec::Executor`; off by default (the disabled executor loop has no
//!   timer calls at all, asserted ≤2% overhead by `tests/profile.rs`).
//! * [`InstrMeta`] — static per-instruction labels (op class, FLOPs,
//!   bytes moved) computed once from the `ExecPlan`.
//! * [`trace`] — bounded span ring + Chrome trace-event JSON export.
//! * [`access_line`] / [`gen_request_id`] — the gateway's structured
//!   one-line access log and request-ID fallback.

pub mod trace;

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

/// Samples kept per instruction for percentile estimates. Older samples
/// are overwritten ring-style; totals/counts keep the full history.
pub const RING_CAP: usize = 64;

/// Coarse op classes used for per-class exec-time counters (Prometheus
/// `dlrt_model_op_class_exec_seconds_total{class=...}`).
pub const OP_CLASSES: [&str; 6] = ["conv", "dense", "pool", "elementwise", "concat", "other"];

/// Number of entries in [`OP_CLASSES`].
pub const N_CLASSES: usize = OP_CLASSES.len();

/// Map an `Op::name()` string to its index in [`OP_CLASSES`].
pub fn op_class(op_name: &str) -> usize {
    match op_name {
        "conv2d" => 0,
        "dense" => 1,
        "maxpool2d" | "global_avg_pool" | "upsample2x" => 2,
        "add" | "relu" | "relu6" | "silu" | "leaky_relu" | "sigmoid" => 3,
        "concat" | "flatten" => 4,
        _ => 5,
    }
}

/// Static per-instruction metadata, computed once from the plan
/// (`ExecPlan::instr_meta`) — labels only, never consulted by execution.
#[derive(Clone, Debug)]
pub struct InstrMeta {
    pub name: String,
    pub op: &'static str,
    /// Index into [`OP_CLASSES`].
    pub class: usize,
    /// Kernel-table index (`uk#idx`) for conv/dense instructions.
    pub kernel_idx: Option<usize>,
    pub out_slot: usize,
    /// FLOPs per batch item (2·MACs for GEMM-backed ops, numel otherwise).
    pub flops: u64,
    /// Activation bytes moved per batch item (f32 reads + writes).
    pub bytes: u64,
    /// Fused-epilogue suffix as the planner prints it, e.g. `+relu +add`.
    pub fused: String,
    /// Reads or writes a channel stripe of a concat root slot.
    pub strided: bool,
    pub in_place: bool,
}

/// Report-time summary of one instruction's samples.
#[derive(Clone, Copy, Debug, Default)]
pub struct InstrStats {
    pub count: u64,
    pub total_s: f64,
    pub mean_s: f64,
    pub p95_s: f64,
}

/// Preallocated per-instruction wall-time recorder.
///
/// Sized for one specific plan (one slot per `Instr`); `record` is the
/// only hot-path entry point and never allocates. The executor skips
/// profiling when the plan length does not match (e.g. after a model
/// swap), so a stale profiler can never index out of bounds.
#[derive(Debug)]
pub struct InstrProfiler {
    /// Op class per instruction, for `drain_class_totals`.
    class_of: Vec<u8>,
    /// `n_instrs × RING_CAP` duration samples, seconds.
    ring: Vec<f64>,
    /// Ring cursor per instruction.
    next: Vec<u32>,
    /// Valid samples per instruction (saturates at `RING_CAP`).
    filled: Vec<u32>,
    count: Vec<u64>,
    total_s: Vec<f64>,
    /// Start offset within the most recent run, for trace export.
    last_start_s: Vec<f64>,
    last_dur_s: Vec<f64>,
    /// Per-class seconds since the last `drain_class_totals`.
    class_s: [f64; N_CLASSES],
    runs: u64,
    run_total_s: f64,
}

impl InstrProfiler {
    /// Preallocate rings for a plan whose instructions have the given op
    /// classes (one entry per `Instr`, values < [`N_CLASSES`]).
    pub fn new(class_of: Vec<u8>) -> InstrProfiler {
        let n = class_of.len();
        InstrProfiler {
            class_of,
            ring: vec![0.0; n * RING_CAP],
            next: vec![0; n],
            filled: vec![0; n],
            count: vec![0; n],
            total_s: vec![0.0; n],
            last_start_s: vec![0.0; n],
            last_dur_s: vec![0.0; n],
            class_s: [0.0; N_CLASSES],
            runs: 0,
            run_total_s: 0.0,
        }
    }

    /// Number of instructions this profiler was sized for.
    pub fn len(&self) -> usize {
        self.class_of.len()
    }

    pub fn is_empty(&self) -> bool {
        self.class_of.is_empty()
    }

    /// Record one execution of instruction `i`: `start_off_s` is the
    /// offset from the start of the current run, `dur_s` the wall time.
    /// Alloc-free; called from the executor's profiled loop.
    #[inline]
    pub fn record(&mut self, i: usize, start_off_s: f64, dur_s: f64) {
        let slot = i * RING_CAP + self.next[i] as usize;
        self.ring[slot] = dur_s;
        self.next[i] = (self.next[i] + 1) % RING_CAP as u32;
        if (self.filled[i] as usize) < RING_CAP {
            self.filled[i] += 1;
        }
        self.count[i] += 1;
        self.total_s[i] += dur_s;
        self.last_start_s[i] = start_off_s;
        self.last_dur_s[i] = dur_s;
        self.class_s[self.class_of[i] as usize] += dur_s;
    }

    /// Close out one full plan execution of `wall_s` seconds.
    #[inline]
    pub fn end_run(&mut self, wall_s: f64) {
        self.runs += 1;
        self.run_total_s += wall_s;
    }

    /// Completed plan executions recorded so far.
    pub fn runs(&self) -> u64 {
        self.runs
    }

    /// Total wall seconds across all recorded runs (whole-loop timing,
    /// including any inter-instruction overhead).
    pub fn run_total_s(&self) -> f64 {
        self.run_total_s
    }

    /// Sum of per-instruction totals — the "covered" time the profile
    /// table accounts for.
    pub fn sum_total_s(&self) -> f64 {
        self.total_s.iter().sum()
    }

    pub fn instr_total_s(&self, i: usize) -> f64 {
        self.total_s[i]
    }

    /// Start offset / duration of instruction `i` in the last run
    /// (seconds), for trace export.
    pub fn last_span_s(&self, i: usize) -> (f64, f64) {
        (self.last_start_s[i], self.last_dur_s[i])
    }

    /// Mean/p95 over the retained ring samples. Allocates (sorts a copy)
    /// — report-time only.
    pub fn stats(&self, i: usize) -> InstrStats {
        let n = self.filled[i] as usize;
        if n == 0 || self.count[i] == 0 {
            return InstrStats::default();
        }
        let mut window: Vec<f64> = self.ring[i * RING_CAP..i * RING_CAP + n].to_vec();
        window.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p95 = window[((n - 1) as f64 * 0.95).round() as usize];
        InstrStats {
            count: self.count[i],
            total_s: self.total_s[i],
            mean_s: self.total_s[i] / self.count[i] as f64,
            p95_s: p95,
        }
    }

    /// Take and reset the per-op-class seconds accumulated since the last
    /// drain — the coordinator feeds these into its metrics after each
    /// batch.
    pub fn drain_class_totals(&mut self) -> [f64; N_CLASSES] {
        std::mem::take(&mut self.class_s)
    }
}

// -- request IDs and access logs -----------------------------------------

static REQ_ID_SEQ: AtomicU64 = AtomicU64::new(1);

/// Generate a request ID for clients that did not supply `X-Request-Id`:
/// process-unique, monotonic, greppable (`req-<pid>-<seq>`).
pub fn gen_request_id() -> String {
    let seq = REQ_ID_SEQ.fetch_add(1, Ordering::Relaxed);
    format!("req-{:x}-{seq:x}", std::process::id())
}

/// Milliseconds since the Unix epoch, for access-log timestamps.
pub fn unix_ms() -> u64 {
    SystemTime::now().duration_since(UNIX_EPOCH).map(|d| d.as_millis() as u64).unwrap_or(0)
}

/// One structured access-log line (space-separated `key=value` pairs; no
/// embedded spaces in values, so it splits cleanly).
#[allow(clippy::too_many_arguments)]
pub fn access_line(
    ts_ms: u64,
    request_id: &str,
    model: &str,
    batch_index: usize,
    batch_size: usize,
    status: u16,
    queue_us: u64,
    exec_us: u64,
    total_us: u64,
) -> String {
    format!(
        "ts={ts_ms} id={request_id} model={model} batch={batch_index}/{batch_size} \
         status={status} queue_us={queue_us} exec_us={exec_us} total_us={total_us}"
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn op_classes_cover_every_op_name() {
        for name in [
            "conv2d",
            "dense",
            "maxpool2d",
            "global_avg_pool",
            "add",
            "concat",
            "upsample2x",
            "relu",
            "relu6",
            "silu",
            "leaky_relu",
            "sigmoid",
            "flatten",
        ] {
            assert!(op_class(name) < N_CLASSES, "{name}");
        }
        assert_eq!(op_class("something_new"), N_CLASSES - 1);
    }

    #[test]
    fn profiler_rings_accumulate_and_wrap() {
        let mut p = InstrProfiler::new(vec![0, 3]);
        assert_eq!(p.len(), 2);
        // overfill the ring: totals keep everything, window keeps RING_CAP
        for rep in 0..(RING_CAP + 10) {
            p.record(0, 0.0, 1e-3);
            p.record(1, 1e-3, 2e-3 * (rep % 2) as f64);
            p.end_run(4e-3);
        }
        let s0 = p.stats(0);
        assert_eq!(s0.count, (RING_CAP + 10) as u64);
        assert!((s0.mean_s - 1e-3).abs() < 1e-12);
        assert!((s0.p95_s - 1e-3).abs() < 1e-12);
        let s1 = p.stats(1);
        assert!(s1.p95_s >= s1.mean_s);
        assert_eq!(p.runs(), (RING_CAP + 10) as u64);
        assert!(p.sum_total_s() <= p.run_total_s() + 1e-12);
        // class drain: instr 0 is class 0 (conv), instr 1 class 3
        let cls = p.drain_class_totals();
        assert!(cls[0] > 0.0 && cls[3] > 0.0);
        assert_eq!(cls[1], 0.0);
        let again = p.drain_class_totals();
        assert!(again.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn stats_on_empty_profiler_are_zero() {
        let p = InstrProfiler::new(vec![0]);
        let s = p.stats(0);
        assert_eq!(s.count, 0);
        assert_eq!(s.mean_s, 0.0);
    }

    #[test]
    fn request_ids_are_unique_and_access_line_is_structured() {
        let a = gen_request_id();
        let b = gen_request_id();
        assert_ne!(a, b);
        assert!(a.starts_with("req-"));
        let line = access_line(123, "rid-1", "resnet18", 2, 4, 200, 10, 20, 35);
        assert_eq!(
            line,
            "ts=123 id=rid-1 model=resnet18 batch=2/4 status=200 \
             queue_us=10 exec_us=20 total_us=35"
        );
        // every field splits as key=value
        for tok in line.split(' ') {
            assert!(tok.contains('='), "{tok}");
        }
    }
}

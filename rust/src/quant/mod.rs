//! Deployment-side quantization: scale fitting, integer codes, packing.
//!
//! The training-side quantizer (LSQ) lives in the JAX build path; this
//! module is the runtime half — it turns f32 weights + scales into the
//! integer codes / packed bitplanes the engines execute, and provides PTQ
//! calibration for models arriving without QAT scales (paper §IV:
//! post-training static quantization).

use crate::dlrt::graph::qp_qn;
use crate::dlrt::tensor::Packed;
use crate::kernels::bitserial::pack_weights_offset;

/// Min/max PTQ scale for a signed `bits`-bit code: maps max|t| onto Q_N.
pub fn calibrate_minmax_signed(t: &[f32], bits: u8) -> f32 {
    let (_, qn) = qp_qn(bits, true);
    let amax = t.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    (amax / qn as f32).max(1e-8)
}

/// Min/max PTQ scale for unsigned activations: maps max(t, 0) onto Q_P.
pub fn calibrate_minmax_unsigned(t: &[f32], bits: u8) -> f32 {
    let (qp, _) = qp_qn(bits, false);
    let tmax = t.iter().fold(0.0f32, |m, v| m.max(*v));
    (tmax / qp as f32).max(1e-8)
}

/// MSE-grid PTQ (paper's static calibration, cf. python quant.calibrate_mse).
pub fn calibrate_mse_signed(t: &[f32], bits: u8, n_grid: usize) -> f32 {
    let base = calibrate_minmax_signed(t, bits);
    let (qp, qn) = qp_qn(bits, true);
    let mut best = (f32::INFINITY, base);
    for g in 0..n_grid {
        let s = base * (0.3 + 0.9 * g as f32 / (n_grid - 1).max(1) as f32);
        let mut mse = 0.0f64;
        for &v in t {
            let q = (v / s).round().clamp(-(qn as f32), qp as f32);
            let d = v - q * s;
            mse += (d * d) as f64;
        }
        if (mse as f32) < best.0 {
            best = (mse as f32, s);
        }
    }
    best.1
}

/// Quantize to signed integer codes in [-Q_N, Q_P].
pub fn quantize_signed(t: &[f32], s: f32, bits: u8) -> Vec<i32> {
    let (qp, qn) = qp_qn(bits, true);
    t.iter()
        .map(|&v| (v / s).round().clamp(-(qn as f32), qp as f32) as i32)
        .collect()
}

/// Quantize to unsigned codes in [0, Q_P].
pub fn quantize_unsigned(t: &[f32], s: f32, bits: u8) -> Vec<u8> {
    let (qp, _) = qp_qn(bits, false);
    t.iter().map(|&v| (v / s).round().clamp(0.0, qp as f32) as u8).collect()
}

/// Quantize + pack conv weights for the bitserial engine.
///
/// `w` is HWIO (kh×kw×cin×cout); the engine wants rows = cout over the
/// (kh, kw, cin) patch — i.e. the transpose the im2col GEMM consumes.
pub fn pack_conv_weights(
    w: &[f32],
    kh: usize,
    kw: usize,
    cin: usize,
    cout: usize,
    s_w: f32,
    bits: u8,
) -> Packed {
    let k = kh * kw * cin;
    debug_assert_eq!(w.len(), k * cout);
    let codes = quantize_signed(w, s_w, bits);
    // transpose HWIO -> (cout, patch)
    let mut byrow = vec![0i32; cout * k];
    for p in 0..k {
        for co in 0..cout {
            byrow[co * k + p] = codes[p * cout + co];
        }
    }
    pack_weights_offset(&byrow, cout, k, bits as usize)
}

/// Transpose HWIO conv weights to (cout, patch) row-major f32 (FP32 engine).
pub fn transpose_conv_weights(w: &[f32], k: usize, cout: usize) -> Vec<f32> {
    debug_assert_eq!(w.len(), k * cout);
    let mut out = vec![0.0f32; cout * k];
    for p in 0..k {
        for co in 0..cout {
            out[co * k + p] = w[p * cout + co];
        }
    }
    out
}

/// Model-size accounting (paper §VII.A: 15.58x compression).
/// Returns (fp32_bytes, packed_bytes) for a conv layer.
pub fn conv_storage_bytes(k: usize, cout: usize, qcfg_bits: Option<u8>) -> (usize, usize) {
    let fp32 = k * cout * 4;
    match qcfg_bits {
        Some(bits) => {
            let words = Packed::words_for(k);
            (fp32, cout * bits as usize * words * 8)
        }
        None => (fp32, fp32),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn minmax_covers_extremes() {
        let t = vec![-0.9, 0.3, 0.45, -0.2];
        let s = calibrate_minmax_signed(&t, 2);
        let codes = quantize_signed(&t, s, 2);
        assert_eq!(*codes.iter().min().unwrap(), -2);
        assert!(codes.iter().all(|&c| (-2..=1).contains(&c)));
    }

    #[test]
    fn mse_beats_or_ties_minmax() {
        prop::check(30, |rng, _| {
            // heavy-tailed samples
            let t: Vec<f32> = (0..256).map(|_| rng.normal().powi(3)).collect();
            let mse = |s: f32| -> f64 {
                t.iter()
                    .map(|&v| {
                        let q = (v / s).round().clamp(-2.0, 1.0);
                        ((v - q * s) * (v - q * s)) as f64
                    })
                    .sum()
            };
            let s_mm = calibrate_minmax_signed(&t, 2);
            let s_mse = calibrate_mse_signed(&t, 2, 40);
            prop::ensure(mse(s_mse) <= mse(s_mm) + 1e-9, "mse calibration regressed")
        });
    }

    #[test]
    fn quantize_error_bounded() {
        prop::check(40, |rng, _| {
            let bits = rng.usize(3) as u8 + 1;
            let (qp, qn) = qp_qn(bits, true);
            let s = 0.2f32;
            let t: Vec<f32> =
                (0..64).map(|_| rng.range_f32(-(qn as f32) * s, qp as f32 * s)).collect();
            let codes = quantize_signed(&t, s, bits);
            for (v, c) in t.iter().zip(&codes) {
                if (v - *c as f32 * s).abs() > s / 2.0 + 1e-5 {
                    return Err(format!("err too big: v={v} c={c}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn pack_conv_weights_transposes() {
        // 1x1 conv, cin=2, cout=2: HWIO = [[w00,w01],[w10,w11]] rows=cin
        let w = vec![0.1, -0.2, 0.1, 0.1]; // (p0,c0) (p0,c1) (p1,c0) (p1,c1)
        let p = pack_conv_weights(&w, 1, 1, 2, 2, 0.1, 2);
        assert_eq!(p.rows, 2);
        assert_eq!(p.k, 2);
        // unpack: codes with offset 2: row0 = [1+2, 1+2], row1 = [-2+2, 1+2]
        assert_eq!(p.unpack(), vec![3, 3, 0, 3]);
    }

    #[test]
    fn storage_accounting() {
        // 3x3x64->64 conv: k=576, fp32 = 147456 B; 2-bit: 64*2*9 words * 8
        let (f, q) = conv_storage_bytes(576, 64, Some(2));
        assert_eq!(f, 147_456);
        assert_eq!(q, 64 * 2 * 9 * 8);
        assert!(f as f32 / q as f32 > 15.0); // the paper's ~16x claim
        let (f2, q2) = conv_storage_bytes(576, 64, None);
        assert_eq!(f2, q2);
    }
}

//! # DeepliteRT (reproduction) — ultra-low-bit quantized inference runtime
//!
//! Rust implementation of the system described in *"Accelerating Deep
//! Learning Model Inference on Arm CPUs with Ultra-Low Bit Quantization and
//! Runtime"* (Deeplite, 2022): a standalone inference engine that executes
//! CNNs whose convolutions are quantized to 1–3 bits using **bitserial**
//! arithmetic — bitplane-packed weights/activations combined with
//! `AND` + `POPCOUNT` word operations:
//!
//! ```text
//!   W · A = Σᵢ Σⱼ POPCOUNT(W[i] & A[j]) << (i + j)
//! ```
//!
//! The paper's Neon kernels map here onto `u64` lanes (`&` +
//! `u64::count_ones`), with the same tiling/threading structure; Arm-target
//! latencies are projected by [`costmodel`]. See DESIGN.md for the full
//! substitution table.
//!
//! ## Crate layout (three-layer architecture)
//!
//! * [`kernels`] — the compute substrate: bitserial, FP32 (im2col + blocked
//!   GEMM) and INT8 engines, pooling, elementwise ops.
//! * [`quant`] — post-training calibration, integer quantization, bitplane
//!   packing (the deployment half of the paper's Neutrino framework).
//! * [`dlrt`] — graph IR + the `.dlrt` deployable model format.
//! * [`compiler`] — `arch.json` + `weights.bin` (exported by the JAX build
//!   path) → quantize → pack → `.dlrt` (the paper's "Deeplite Compiler").
//! * [`exec`] — execution planner (pass pipeline: activation fusion,
//!   in-place/alias lowering, arena slot assignment) + the arena executor
//!   that runs the lowered plan with zero steady-state allocation.
//! * [`runtime`] — PJRT client wrapper that loads JAX-AOT HLO artifacts
//!   (the framework-baseline engine; python never runs at request time).
//!   Gated behind the off-by-default `pjrt` cargo feature: it needs the
//!   `xla` crate + an XLA toolchain, which plain toolchains lack.
//! * [`coordinator`] — serving layer: request router, dynamic batcher,
//!   worker pool, detection postprocessing.
//! * [`serve`] — network gateway: std-only threaded HTTP/1.1 server,
//!   multi-model registry, admission control (bounded queues sized from
//!   the plan's memory footprint), Prometheus `/metrics`, and the
//!   `dlrt client` load generator.
//! * [`obs`] — observability: zero-steady-state-allocation per-instruction
//!   profiler rings, request-scoped span tracing with Chrome trace-event
//!   export, structured access logs (`dlrt profile`, `GET /v1/debug/trace`).
//! * [`costmodel`] — analytical Cortex-A53/A72/A57 latency projection.
//! * [`tune`] — `dlrt tune`: on-device schedule search over micro-kernel
//!   tile geometry / thread splits / im2col staging, persisted to a
//!   versioned tuning DB the compiler and `.dlrt` loader consult.
//! * [`models`] — native graph builders for the paper's evaluation models.
//! * [`bench_harness`] — timing + paper-table reporting used by `cargo bench`.
//! * [`util`] — hand-rolled substrates for this offline environment: JSON
//!   codec, xorshift RNG, mini property-test driver, CLI parsing.

// The unsafe surface (arena slot views, the lifetime-erased worker-pool
// jobs) is small and audited: every unsafe operation must sit in an explicit
// `unsafe` block carrying a `// SAFETY:` comment. CI compiles with
// `-D warnings`, which turns both lints into hard errors there.
#![deny(unsafe_op_in_unsafe_fn)]
#![warn(clippy::undocumented_unsafe_blocks)]

pub mod bench_harness;
pub mod compiler;
pub mod coordinator;
pub mod costmodel;
pub mod dlrt;
pub mod exec;
pub mod kernels;
pub mod models;
pub mod obs;
pub mod quant;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod serve;
pub mod tune;
pub mod util;

pub use dlrt::graph::{Graph, Node, Op, QCfg};
pub use dlrt::tensor::Tensor;

//! Minimal JSON parser / writer (serde stand-in for this offline build).
//!
//! Supports the full JSON grammar minus exotic number forms; numbers are
//! parsed as `f64` (the interchange files only carry f32-precision values
//! and small integers, both exact in f64). Parsing is recursive-descent
//! over bytes; no allocation tricks, just correctness + enough speed for
//! multi-megabyte golden files.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value. Object keys are ordered (BTreeMap) so round-trips
/// are deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking for {key:?})"),
        }
    }
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }
    pub fn num(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number"),
        }
    }
    pub fn usize(&self) -> Result<usize> {
        let n = self.num()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a usize: {n}");
        }
        Ok(n as usize)
    }
    pub fn i64(&self) -> Result<i64> {
        let n = self.num()?;
        if n.fract() != 0.0 {
            bail!("not an integer: {n}");
        }
        Ok(n as i64)
    }
    pub fn f32(&self) -> Result<f32> {
        Ok(self.num()? as f32)
    }
    pub fn bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool"),
        }
    }
    pub fn arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array"),
        }
    }
    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.arr()?.iter().map(|v| v.usize()).collect()
    }
    pub fn f32_vec(&self) -> Result<Vec<f32>> {
        self.arr()?.iter().map(|v| v.f32()).collect()
    }
    pub fn i32_vec(&self) -> Result<Vec<i32>> {
        self.arr()?.iter().map(|v| Ok(v.i64()? as i32)).collect()
    }

    // -- writer ----------------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for report writing.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}
pub fn num(n: f64) -> Json {
    Json::Num(n)
}
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected EOF"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, got {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(key, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']' got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // surrogate pairs unsupported (not produced by our writers)
                            s.push(char::from_u32(cp).ok_or_else(|| anyhow!("bad codepoint"))?);
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                _ => {
                    // copy UTF-8 continuation bytes verbatim
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    if self.i > self.b.len() {
                        bail!("truncated UTF-8");
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(txt.parse::<f64>().map_err(|e| anyhow!("bad number {txt:?}: {e}"))?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().str().unwrap(), "x");
        assert!(!v.get("a").unwrap().arr().unwrap()[2].get("b").unwrap().bool().unwrap());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,null,true,"s"],"obj":{"k":-3}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse("\"caf\u{e9} \\u00e9\"").unwrap();
        assert_eq!(v.str().unwrap(), "café é");
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 3, "xs": [1.5, 2.5]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().usize().unwrap(), 3);
        assert_eq!(v.get("xs").unwrap().f32_vec().unwrap(), vec![1.5, 2.5]);
        assert!(v.get("nope").is_err());
        assert!(v.get("n").unwrap().str().is_err());
    }
}

//! Hand-rolled substrates: JSON codec, RNG, property testing, CLI, threads.
//!
//! The build environment is offline with a fixed vendored crate set (no
//! serde / rayon / clap / proptest / criterion), so the small pieces those
//! crates would provide are implemented here, each with its own tests.

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod threads;

//! Mini property-test driver (proptest stand-in).
//!
//! Runs a closure over many seeded random cases; on failure it reports the
//! failing case number and seed so the case can be replayed exactly:
//!
//! ```ignore
//! prop::check(200, |rng, case| {
//!     let k = rng.usize(100) + 1;
//!     ...
//!     prop::ensure(cond, format!("k={k}"))
//! });
//! ```

use super::rng::Rng;

pub type CaseResult = Result<(), String>;

/// Run `cases` random cases. Panics with seed + message on first failure.
pub fn check<F: FnMut(&mut Rng, u64) -> CaseResult>(cases: u64, mut f: F) {
    // fixed master seed: reproducible CI; per-case seeds are derived so a
    // failing case can be replayed in isolation with `replay`.
    for case in 0..cases {
        let seed = dl_seed(case);
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng, case) {
            panic!("property failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Re-run a single failing case by its number.
pub fn replay<F: FnMut(&mut Rng, u64) -> CaseResult>(case: u64, mut f: F) -> CaseResult {
    let mut rng = Rng::new(dl_seed(case));
    f(&mut rng, case)
}

fn dl_seed(case: u64) -> u64 {
    0xd117_0000_0000_0000 ^ case.wrapping_mul(0x2545f4914f6cdd1d)
}

/// Assertion helper producing a `CaseResult`.
pub fn ensure(cond: bool, msg: impl Into<String>) -> CaseResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Compare two f32 slices with absolute + relative tolerance.
pub fn close(a: &[f32], b: &[f32], rtol: f32, atol: f32) -> CaseResult {
    ensure(a.len() == b.len(), format!("len {} vs {}", a.len(), b.len()))?;
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if (x - y).abs() > tol {
            return Err(format!("elem {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivially() {
        check(50, |rng, _| {
            let v = rng.f32();
            ensure((0.0..1.0).contains(&v), "rng out of range")
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn check_reports_failure() {
        check(50, |rng, _| ensure(rng.f32() < 0.5, "flaky"));
    }

    #[test]
    fn replay_is_deterministic() {
        let mut seen = Vec::new();
        check(5, |rng, case| {
            seen.push((case, rng.next_u64()));
            Ok(())
        });
        for (case, val) in seen {
            replay(case, |rng, _| {
                ensure(rng.next_u64() == val, "replay mismatch")
            })
            .unwrap();
        }
    }

    #[test]
    fn close_tolerances() {
        assert!(close(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5, 1e-6).is_ok());
        assert!(close(&[1.0], &[1.1], 1e-5, 1e-6).is_err());
        assert!(close(&[1.0], &[1.0, 2.0], 0.1, 0.1).is_err());
    }
}

//! Persistent-pool data-parallel helpers (rayon stand-in).
//!
//! The kernels parallelize over output rows the way the paper's Arm kernels
//! parallelize over output tiles: disjoint chunks, no shared mutable state.
//!
//! Earlier revisions spawned fresh OS threads per GEMM call via
//! `std::thread::scope`; under serving load that put a thread-spawn on every
//! inference. The pool below is created once ([`global`]) and reused by every
//! kernel call for the lifetime of the process: callers enqueue
//! lifetime-erased range jobs, run the first chunk themselves, help drain
//! their own remaining jobs, and block until a stack-allocated latch reaches
//! zero — which is also what makes the lifetime erasure sound (the borrowed
//! closure and latch outlive every job execution).
//!
//! `par_ranges` / `par_chunks_rows` keep their original signatures, so all
//! kernels migrated to the pool transparently.
//!
//! Beyond the process-wide [`global`] pool, replica executors create
//! *private* pools ([`ThreadPool::pinned`]) whose workers are pinned to a
//! core subset; a thread installs one with [`set_current_pool`] and every
//! `par_ranges` call made from that thread dispatches to it instead of the
//! global pool. Threads that never install a pool keep the old behavior
//! exactly.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::{JoinHandle, Thread, ThreadId};
use std::time::Duration;

/// Number of worker threads to use by default (overridable per call).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// One unit of work: `call(ctx, lo, hi)` then count down `latch`.
///
/// `ctx` points at the submitting call's closure and `latch` at its stack
/// frame; both stay valid because `run_partitioned` blocks until the latch
/// reaches zero before returning.
struct Job {
    call: unsafe fn(*const (), usize, usize),
    ctx: *const (),
    lo: usize,
    hi: usize,
    latch: *const Latch,
}

// SAFETY: the raw pointers are only dereferenced while the submitting thread
// is blocked in `run_partitioned`, which keeps the pointees alive.
unsafe impl Send for Job {}

unsafe fn call_closure<F: Fn(usize, usize) + Sync>(ctx: *const (), lo: usize, hi: usize) {
    // SAFETY: `ctx` was erased from an `&F` by `run_partitioned`, whose stack
    // frame (and therefore the closure) stays alive until the latch reaches
    // zero — i.e. until after every job built from it has finished running.
    unsafe { (*(ctx as *const F))(lo, hi) }
}

/// Runs one job, counting the latch down even if the closure panics; the
/// panic is recorded on the latch and re-raised on the submitting thread
/// (matching the old `thread::scope` propagation). Never unwinds, so pool
/// workers survive panicking jobs and latches always reach zero.
fn run_job(job: &Job) {
    let result = catch_unwind(AssertUnwindSafe(|| {
        // SAFETY: `ctx` points at the submitting call's closure, which is
        // kept alive because the submitter blocks on the latch until this
        // job (and every sibling) has counted down.
        unsafe { (job.call)(job.ctx, job.lo, job.hi) }
    }));
    // SAFETY: the latch outlives the job (the submitter waits on it).
    let latch = unsafe { &*job.latch };
    if result.is_err() {
        latch.poisoned.store(true, Ordering::Release);
    }
    latch.count_down(); // must be the last touch of the latch
}

/// Stack-allocated completion latch — no heap allocation per kernel call.
struct Latch {
    remaining: AtomicUsize,
    poisoned: AtomicBool,
    owner: Thread,
}

impl Latch {
    fn new(count: usize) -> Latch {
        Latch {
            remaining: AtomicUsize::new(count),
            poisoned: AtomicBool::new(false),
            owner: std::thread::current(),
        }
    }

    fn count_down(&self) {
        // Clone the handle BEFORE the decrement: the instant the owner can
        // observe zero it may return and pop this latch off its stack, so
        // `self` must not be touched after the fetch_sub.
        let owner = self.owner.clone();
        if self.remaining.fetch_sub(1, Ordering::Release) == 1 {
            owner.unpark();
        }
    }

    fn done(&self) -> bool {
        self.remaining.load(Ordering::Acquire) == 0
    }
}

struct PoolShared {
    queue: Mutex<VecDeque<Job>>,
    cv: Condvar,
    /// Set (under the queue lock) by [`ThreadPool::shutdown`]; workers exit
    /// once it is set and the queue has drained.
    stop: AtomicBool,
}

/// A persistent pool of kernel worker threads (plus the caller, which always
/// executes the first chunk and helps drain its own jobs).
pub struct ThreadPool {
    shared: Arc<PoolShared>,
    worker_ids: Vec<ThreadId>,
    /// Join handles for [`shutdown`](ThreadPool::shutdown); the global pool
    /// never joins, private replica pools do on model drain.
    handles: Mutex<Vec<JoinHandle<()>>>,
}

impl ThreadPool {
    fn with_workers(workers: usize) -> ThreadPool {
        ThreadPool::spawn("dlrt-pool", workers, Vec::new())
    }

    /// A private pool whose workers are pinned to `cores` (best effort;
    /// no-op off Linux or when `cores` is empty). Replica executors use one
    /// per replica so models stop contending for the global pool.
    pub fn pinned(workers: usize, cores: &[usize]) -> Arc<ThreadPool> {
        Arc::new(ThreadPool::spawn("dlrt-replica", workers, cores.to_vec()))
    }

    fn spawn(prefix: &str, workers: usize, cores: Vec<usize>) -> ThreadPool {
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            stop: AtomicBool::new(false),
        });
        let mut worker_ids = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let sh = shared.clone();
            let cores = cores.clone();
            let handle = std::thread::Builder::new()
                .name(format!("{prefix}-{i}"))
                .spawn(move || {
                    pin_to_cores(&cores);
                    worker_loop(&sh)
                })
                .expect("spawning pool worker");
            worker_ids.push(handle.thread().id());
            handles.push(handle);
        }
        ThreadPool { shared, worker_ids, handles: Mutex::new(handles) }
    }

    /// Stop the workers once the queue drains and join them. Safe to call
    /// more than once; submitting after shutdown would hang, so callers
    /// (replica drains) shut down only after their executors are gone.
    pub fn shutdown(&self) {
        {
            // set under the queue lock so a worker between "queue empty" and
            // "wait" cannot miss the wakeup
            let _q = self.shared.queue.lock().unwrap();
            self.shared.stop.store(true, Ordering::Release);
        }
        self.shared.cv.notify_all();
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }

    /// Number of pooled worker threads (callers add themselves per call).
    pub fn workers(&self) -> usize {
        self.worker_ids.len()
    }

    /// Thread ids of the pooled workers (stable for the process lifetime —
    /// the pool-reuse test asserts kernel chunks never run anywhere else).
    pub fn worker_ids(&self) -> &[ThreadId] {
        &self.worker_ids
    }

    /// Run `f` over the `chunk_ranges(n, nchunks)` partition: chunk 0 inline
    /// on the caller, the rest on the pool. Allocation-free per call (the
    /// chunk iterator lives on the stack, the latch too).
    fn run_partitioned<F>(&self, n: usize, nchunks: usize, f: &F)
    where
        F: Fn(usize, usize) + Sync,
    {
        let mut chunks = chunk_ranges(n, nchunks);
        let (first_lo, first_hi) = chunks.next().expect("run_partitioned requires n > 0");
        let offloaded = chunks.clone().count();
        let latch = Latch::new(offloaded);
        {
            let mut q = self.shared.queue.lock().unwrap();
            for (lo, hi) in chunks {
                q.push_back(Job {
                    call: call_closure::<F>,
                    ctx: f as *const F as *const (),
                    lo,
                    hi,
                    latch: &latch,
                });
            }
        }
        self.shared.cv.notify_all();
        // The inline chunk runs under catch_unwind: this frame holds the
        // closure and latch the queued jobs point at, so it must stay alive
        // until the latch hits zero even if our own chunk panics.
        let inline = catch_unwind(AssertUnwindSafe(|| f(first_lo, first_hi)));
        // Help drain our own jobs (never other callers' — keeps chunk
        // execution on pool workers + the submitting thread only, and makes
        // nested submission from a worker deadlock-free), then wait.
        while !latch.done() {
            if let Some(job) = self.pop_job_for(&latch) {
                run_job(&job);
            } else {
                std::thread::park_timeout(Duration::from_micros(100));
            }
        }
        // All jobs are done; the borrowed closure/latch are no longer
        // referenced anywhere, so panics may propagate to the caller now.
        if let Err(payload) = inline {
            resume_unwind(payload);
        }
        if latch.poisoned.load(Ordering::Acquire) {
            panic!("a kernel chunk panicked on the worker pool");
        }
    }

    fn pop_job_for(&self, latch: *const Latch) -> Option<Job> {
        let mut q = self.shared.queue.lock().unwrap();
        let idx = q.iter().position(|j| std::ptr::eq(j.latch, latch))?;
        q.remove(idx)
    }
}

fn worker_loop(shared: &PoolShared) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break Some(j);
                }
                if shared.stop.load(Ordering::Acquire) {
                    break None;
                }
                q = shared.cv.wait(q).unwrap();
            }
        };
        match job {
            Some(j) => run_job(&j),
            None => return,
        }
    }
}

/// Pin the calling thread to `cores` (best effort). Linux-only; a no-op
/// elsewhere and under Miri (which cannot interpret the syscall).
#[cfg(all(target_os = "linux", not(miri)))]
pub fn pin_to_cores(cores: &[usize]) {
    if cores.is_empty() {
        return;
    }
    // cpu_set_t is a 1024-bit mask on Linux; declared by hand because the
    // repo links no libc crate (the symbol itself is always in libc).
    let mut mask = [0u64; 16];
    for &c in cores {
        if c < 1024 {
            mask[c / 64] |= 1u64 << (c % 64);
        }
    }
    extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }
    // SAFETY: pid 0 targets the calling thread; the mask pointer and length
    // describe a live, correctly-sized local buffer. Failure is ignored —
    // pinning is a performance hint, never a correctness requirement.
    let _ = unsafe { sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) };
}

/// Pin the calling thread to `cores` (best effort). Linux-only; a no-op
/// elsewhere and under Miri (which cannot interpret the syscall).
#[cfg(not(all(target_os = "linux", not(miri))))]
pub fn pin_to_cores(_cores: &[usize]) {}

thread_local! {
    /// The pool `par_ranges` dispatches to from this thread; `None` means
    /// the process-wide [`global`] pool.
    static CURRENT_POOL: RefCell<Option<Arc<ThreadPool>>> = const { RefCell::new(None) };
}

/// Install (or clear) the pool that `par_ranges` calls made from *this
/// thread* dispatch to. Replica batch workers install their replica's
/// private pinned pool at startup; everything else keeps the global pool.
pub fn set_current_pool(pool: Option<Arc<ThreadPool>>) {
    CURRENT_POOL.with(|p| *p.borrow_mut() = pool);
}

/// The process-wide kernel pool, created on first use and reused by every
/// subsequent kernel call. Long-lived components (executors, coordinator
/// workers) grab this handle once so steady-state traffic never pays
/// thread-spawn latency.
pub fn global() -> &'static ThreadPool {
    static POOL: OnceLock<ThreadPool> = OnceLock::new();
    POOL.get_or_init(|| ThreadPool::with_workers((default_threads() - 1).max(1)))
}

/// Raw-pointer wrapper so chunk base addresses can be captured by a `Sync`
/// closure; soundness comes from workers slicing disjoint row ranges.
struct SendPtr<T>(*mut T);
// SAFETY: only the pointer *value* crosses threads; every dereference slices
// a disjoint row range per worker (see `par_chunks_rows`), so no two threads
// ever touch the same bytes.
unsafe impl<T> Send for SendPtr<T> {}
// SAFETY: shared access hands out only the raw pointer; mutation happens
// through per-worker `&mut` sub-slices over disjoint ranges.
unsafe impl<T> Sync for SendPtr<T> {}

/// Split `out` into up to `nthreads` contiguous chunks of whole `row_len`
/// rows and run `f(first_row_index, chunk)` on each in parallel (pool).
pub fn par_chunks_rows<T, F>(out: &mut [T], row_len: usize, nthreads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let rows = if row_len == 0 { 0 } else { out.len() / row_len };
    let nthreads = nthreads.max(1).min(rows.max(1));
    if nthreads <= 1 || rows == 0 {
        f(0, out);
        return;
    }
    let total = out.len();
    let base = SendPtr(out.as_mut_ptr());
    par_ranges(rows, nthreads, |lo, hi| {
        let start = lo * row_len;
        // the final chunk absorbs any trailing partial row
        let len = if hi == rows { total - start } else { (hi - lo) * row_len };
        // SAFETY: row ranges [lo, hi) are disjoint across workers, so the
        // derived &mut sub-slices never alias.
        let chunk = unsafe { std::slice::from_raw_parts_mut(base.0.add(start), len) };
        f(lo, chunk);
    });
}

/// The exact contiguous partition of `[0, n)` that `par_ranges` dispatches
/// across `nthreads` workers: `ceil(n / nt)` items per chunk, the final chunk
/// clipped to `n`, trailing empty chunks dropped. Chunks come out in order
/// and tile `[0, n)` exactly.
///
/// Public so the plan verifier (`exec::verify`) can re-derive the worker row
/// partition and prove per-thread write ranges disjoint against the same
/// arithmetic the pool actually executes — if this function changes, the
/// race proof re-runs against the new partition automatically.
pub fn chunk_ranges(n: usize, nthreads: usize) -> impl Iterator<Item = (usize, usize)> + Clone {
    let nt = nthreads.max(1).min(n.max(1));
    let per = n.div_ceil(nt).max(1);
    (0..nt).map(move |t| (t * per, ((t + 1) * per).min(n))).take_while(move |&(lo, _)| lo < n)
}

/// Parallel-for over a range, chunked contiguously: `f(lo, hi)` per worker.
pub fn par_ranges<F>(n: usize, nthreads: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let nthreads = nthreads.max(1).min(n);
    if nthreads <= 1 {
        f(0, n);
        return;
    }
    // Arc clone (refcount bump, no allocation) instead of holding the
    // RefCell borrow across the dispatch, so nested par_ranges from a job
    // closure stays legal.
    let pool = CURRENT_POOL.with(|p| p.borrow().clone());
    match pool {
        Some(pool) => pool.run_partitioned(n, nthreads, &f),
        None => global().run_partitioned(n, nthreads, &f),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeSet;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex;

    #[test]
    fn chunk_ranges_tiles_the_range_exactly_and_in_order() {
        for n in [0usize, 1, 2, 7, 64, 97, 103, 1000] {
            for t in [1usize, 2, 3, 4, 8, 200] {
                let mut expect = 0;
                for (lo, hi) in chunk_ranges(n, t) {
                    assert_eq!(lo, expect, "gap or overlap at n={n} t={t}");
                    assert!(hi > lo, "empty chunk at n={n} t={t}");
                    expect = hi;
                }
                assert_eq!(expect, n, "partition must cover [0, {n}) for t={t}");
            }
        }
    }

    #[test]
    fn par_chunks_covers_all_rows() {
        let mut data = vec![0.0f32; 7 * 5];
        par_chunks_rows(&mut data, 5, 3, |row0, chunk| {
            for (i, row) in chunk.chunks_mut(5).enumerate() {
                for v in row.iter_mut() {
                    *v = (row0 + i) as f32;
                }
            }
        });
        for r in 0..7 {
            for c in 0..5 {
                assert_eq!(data[r * 5 + c], r as f32);
            }
        }
    }

    #[test]
    fn par_ranges_partitions_exactly() {
        let count = AtomicUsize::new(0);
        par_ranges(103, 4, |lo, hi| {
            count.fetch_add(hi - lo, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 103);
    }

    #[test]
    fn single_thread_fallback() {
        let mut data = vec![0.0f32; 4];
        par_chunks_rows(&mut data, 2, 1, |row0, chunk| {
            assert_eq!(row0, 0);
            assert_eq!(chunk.len(), 4);
        });
        par_ranges(0, 4, |_, _| panic!("no work expected"));
    }

    #[test]
    fn par_chunks_rows_is_generic_over_element_type() {
        let mut data = vec![0i32; 9 * 4];
        par_chunks_rows(&mut data, 4, 4, |row0, chunk| {
            for (i, row) in chunk.chunks_mut(4).enumerate() {
                row.fill((row0 + i) as i32);
            }
        });
        for r in 0..9 {
            assert!(data[r * 4..(r + 1) * 4].iter().all(|&v| v == r as i32));
        }
    }

    #[test]
    fn pool_reuses_threads_across_calls() {
        // every chunk of every call must land on a persistent pool worker or
        // on the calling thread — i.e. no per-call thread spawning.
        let seen = Mutex::new(BTreeSet::new());
        // Miri runs the same path, just fewer repetitions (it interprets
        // every instruction; 32 pool round-trips would dominate the CI job).
        let reps = if cfg!(miri) { 4 } else { 32 };
        for _ in 0..reps {
            par_ranges(64, 4, |_, _| {
                seen.lock().unwrap().insert(std::thread::current().id());
            });
        }
        let seen = seen.into_inner().unwrap();
        let pool = global();
        let mut allowed: BTreeSet<ThreadId> = pool.worker_ids().iter().copied().collect();
        allowed.insert(std::thread::current().id());
        assert!(
            seen.is_subset(&allowed),
            "kernel chunks ran outside the persistent pool (per-call spawning?)"
        );
        assert!(seen.len() <= pool.workers() + 1);
    }

    #[test]
    fn panicking_chunk_propagates_and_pool_survives() {
        // a panic in any chunk must reach the submitting thread (as with
        // thread::scope), and must not kill pool workers or leak jobs
        let res = std::panic::catch_unwind(|| {
            par_ranges(64, 4, |lo, _| {
                assert!(lo == 0, "boom on a pooled chunk");
            });
        });
        assert!(res.is_err(), "worker panic was swallowed");
        let count = AtomicUsize::new(0);
        par_ranges(50, 4, |lo, hi| {
            count.fetch_add(hi - lo, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 50, "pool unusable after a panic");
    }

    #[test]
    // 8 caller threads × 20 submissions is minutes under the interpreter;
    // the single-caller pool tests above already cover the erased-job +
    // latch machinery Miri is here to check.
    #[cfg_attr(miri, ignore)]
    fn concurrent_callers_share_the_pool() {
        // Loom-free smoke test: many threads hammer the shared pool at once;
        // every call must still see exactly its own partition.
        let handles: Vec<_> = (0..8)
            .map(|t| {
                std::thread::spawn(move || {
                    for rep in 0..20 {
                        let n = 97 + t * 13 + rep;
                        let sum = AtomicUsize::new(0);
                        par_ranges(n, 4, |lo, hi| {
                            sum.fetch_add((lo..hi).sum::<usize>(), Ordering::Relaxed);
                        });
                        assert_eq!(sum.load(Ordering::SeqCst), n * (n - 1) / 2);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn private_pool_serves_par_ranges_and_shuts_down() {
        // with a private pool installed, chunks must land on ITS workers (or
        // the caller) — never on the global pool
        let pool = ThreadPool::pinned(2, &[]);
        set_current_pool(Some(pool.clone()));
        let seen = Mutex::new(BTreeSet::new());
        let reps = if cfg!(miri) { 2 } else { 16 };
        for _ in 0..reps {
            par_ranges(60, 3, |_, _| {
                seen.lock().unwrap().insert(std::thread::current().id());
            });
        }
        set_current_pool(None);
        let seen = seen.into_inner().unwrap();
        let mut allowed: BTreeSet<ThreadId> = pool.worker_ids().iter().copied().collect();
        allowed.insert(std::thread::current().id());
        assert!(seen.is_subset(&allowed), "chunks escaped the private pool");
        pool.shutdown();
        pool.shutdown(); // idempotent
        // this thread's par_ranges falls back to the global pool again
        let count = AtomicUsize::new(0);
        par_ranges(30, 3, |lo, hi| {
            count.fetch_add(hi - lo, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 30);
    }

    #[test]
    fn shutdown_waits_for_queued_jobs() {
        let pool = ThreadPool::pinned(2, &[]);
        set_current_pool(Some(pool.clone()));
        let count = AtomicUsize::new(0);
        par_ranges(100, 3, |lo, hi| {
            count.fetch_add(hi - lo, Ordering::SeqCst);
        });
        set_current_pool(None);
        pool.shutdown();
        assert_eq!(count.load(Ordering::SeqCst), 100);
    }
}

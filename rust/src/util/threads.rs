//! Scoped data-parallel helpers (rayon stand-in).
//!
//! The kernels parallelize over output rows the way the paper's Arm kernels
//! parallelize over output tiles: disjoint chunks, no shared mutable state.
//! Built on `std::thread::scope`, so borrows of the surrounding stack work.

/// Number of worker threads to use by default (overridable per call).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Split `out` into `nthreads` contiguous chunks of whole `row_len` rows and
/// run `f(first_row_index, chunk)` on each in parallel.
pub fn par_chunks_rows<F>(out: &mut [f32], row_len: usize, nthreads: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    let rows = out.len() / row_len;
    let nthreads = nthreads.max(1).min(rows.max(1));
    if nthreads <= 1 || rows == 0 {
        f(0, out);
        return;
    }
    let rows_per = rows.div_ceil(nthreads);
    std::thread::scope(|scope| {
        let mut rest = out;
        let mut row0 = 0;
        while !rest.is_empty() {
            let take = (rows_per * row_len).min(rest.len());
            let (chunk, tail) = rest.split_at_mut(take);
            let fref = &f;
            let start = row0;
            scope.spawn(move || fref(start, chunk));
            row0 += take / row_len;
            rest = tail;
        }
    });
}

/// Parallel-for over a range, chunked contiguously: `f(lo, hi)` per worker.
pub fn par_ranges<F>(n: usize, nthreads: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let nthreads = nthreads.max(1).min(n);
    if nthreads <= 1 {
        f(0, n);
        return;
    }
    let per = n.div_ceil(nthreads);
    std::thread::scope(|scope| {
        for t in 0..nthreads {
            let lo = t * per;
            let hi = ((t + 1) * per).min(n);
            if lo >= hi {
                break;
            }
            let fref = &f;
            scope.spawn(move || fref(lo, hi));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn par_chunks_covers_all_rows() {
        let mut data = vec![0.0f32; 7 * 5];
        par_chunks_rows(&mut data, 5, 3, |row0, chunk| {
            for (i, row) in chunk.chunks_mut(5).enumerate() {
                for v in row.iter_mut() {
                    *v = (row0 + i) as f32;
                }
            }
        });
        for r in 0..7 {
            for c in 0..5 {
                assert_eq!(data[r * 5 + c], r as f32);
            }
        }
    }

    #[test]
    fn par_ranges_partitions_exactly() {
        let count = AtomicUsize::new(0);
        par_ranges(103, 4, |lo, hi| {
            count.fetch_add(hi - lo, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 103);
    }

    #[test]
    fn single_thread_fallback() {
        let mut data = vec![0.0f32; 4];
        par_chunks_rows(&mut data, 2, 1, |row0, chunk| {
            assert_eq!(row0, 0);
            assert_eq!(chunk.len(), 4);
        });
        par_ranges(0, 4, |_, _| panic!("no work expected"));
    }
}

//! Small deterministic RNG (xoshiro256**) — rand-crate stand-in.
//!
//! Used for weight initialization in native model builders, workload
//! generation in benches, and the mini property-test driver. Deterministic
//! across platforms so golden-based tests stay stable.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 seeding, as recommended by the xoshiro authors
        let mut x = seed.wrapping_add(0x9e3779b97f4a7c15);
        let mut s = [0u64; 4];
        for slot in &mut s {
            x = x.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            *slot = z ^ (z >> 31);
        }
        Rng { s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [lo, hi) (hi > lo).
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi > lo);
        lo + (self.next_u64() % (hi - lo) as u64) as i64
    }

    pub fn usize(&mut self, hi: usize) -> usize {
        debug_assert!(hi > 0);
        (self.next_u64() % hi as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f32().max(1e-12);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Fill with He-normal initialized weights (fan_in based).
    pub fn he_normal(&mut self, n: usize, fan_in: usize) -> Vec<f32> {
        let std = (2.0 / fan_in as f32).sqrt();
        (0..n).map(|_| self.normal() * std).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn range_respects_bounds() {
        let mut r = Rng::new(2);
        let mut seen_lo = false;
        for _ in 0..10_000 {
            let v = r.range(-3, 5);
            assert!((-3..5).contains(&v));
            seen_lo |= v == -3;
        }
        assert!(seen_lo);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let xs: Vec<f32> = (0..50_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}

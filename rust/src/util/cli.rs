//! Tiny CLI argument helper (clap stand-in): `--key value` / `--flag` pairs.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse raw argv (excluding program name and subcommand).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        out.options.insert(key.to_string(), it.next().unwrap());
                    }
                    _ => out.flags.push(key.to_string()),
                }
            } else if a.starts_with('-') && a.len() > 1 {
                bail!("short options not supported: {a}");
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn require(&self, key: &str) -> Result<&str> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing required --{key}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn mixed_args() {
        let a = parse("model.dlrt --threads 4 --verbose --scale 0.5 extra");
        assert_eq!(a.positional, vec!["model.dlrt", "extra"]);
        assert_eq!(a.usize_or("threads", 1).unwrap(), 4);
        assert!(a.flag("verbose"));
        assert_eq!(a.f64_or("scale", 1.0).unwrap(), 0.5);
        assert_eq!(a.get_or("missing", "d"), "d");
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("--a --b v --c");
        assert!(a.flag("a") && a.flag("c"));
        assert_eq!(a.get("b"), Some("v"));
    }

    #[test]
    fn require_errors() {
        assert!(parse("--x 1").require("y").is_err());
    }
}

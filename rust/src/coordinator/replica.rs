//! Replica executor pools: per-model, per-replica kernel thread pools
//! pinned to disjoint core slices.
//!
//! With one shared kernel pool, every model (and every batch worker) in
//! the process contends for the same threads — under mixed-model load a
//! heavy model's GEMMs stall a light model's. A *replica* is an
//! independent execution unit: `ServerConfig::workers` batch workers plus
//! a private [`ThreadPool`] of `threads_per_worker - 1` kernel threads,
//! optionally pinned ([`ServerConfig::pin_cores`]) to a core slice carved
//! out of the host with the same [`chunk_ranges`] arithmetic the kernels
//! partition rows with. Replicas share the model's request queue — the
//! batcher stays one — but never share kernel threads.
//!
//! The default (`replicas = 1`, unpinned) builds no private pool at all:
//! batch workers keep using the process-wide global pool, which preserves
//! the pre-replica behavior (and its tests) exactly.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::util::threads::{self, chunk_ranges, ThreadPool};

use super::ServerConfig;

/// One replica's execution state: occupancy counter, core slice, and the
/// private kernel pool its batch workers dispatch to (None = global pool).
pub struct ReplicaState {
    /// batch workers of this replica currently executing a batch
    busy: AtomicU64,
    /// batch workers in this replica
    pub workers: usize,
    /// cores this replica's threads pin to (empty = unpinned)
    pub cores: Vec<usize>,
    pool: Option<Arc<ThreadPool>>,
}

impl ReplicaState {
    /// Batch workers of this replica currently executing.
    pub fn busy(&self) -> u64 {
        self.busy.load(Ordering::Relaxed)
    }

    pub(super) fn enter(&self) {
        self.busy.fetch_add(1, Ordering::Relaxed);
    }

    pub(super) fn leave(&self) {
        self.busy.fetch_sub(1, Ordering::Relaxed);
    }

    /// Called once by each batch worker at startup: pin to the replica's
    /// cores and route this thread's `par_ranges` calls to the replica's
    /// private pool (when it has one).
    pub(super) fn install_on_current_thread(&self) {
        if !self.cores.is_empty() {
            threads::pin_to_cores(&self.cores);
        }
        if let Some(pool) = &self.pool {
            threads::set_current_pool(Some(pool.clone()));
        }
    }

    /// Stop the private pool (no-op for global-pool replicas). Called after
    /// the batch workers have been joined, so no job can arrive later.
    pub(super) fn shutdown_pool(&self) {
        if let Some(pool) = &self.pool {
            pool.shutdown();
        }
    }
}

/// Build the per-replica states for `cfg` (already clamped: `replicas` and
/// `workers` are >= 1). Core slices split the host's cores evenly across
/// replicas; when there are more replicas than cores the slices wrap.
pub(super) fn build_replicas(cfg: &ServerConfig) -> Vec<Arc<ReplicaState>> {
    let private = cfg.replicas > 1 || cfg.pin_cores;
    let slices: Vec<Vec<usize>> = if cfg.pin_cores {
        chunk_ranges(threads::default_threads(), cfg.replicas)
            .map(|(lo, hi)| (lo..hi).collect())
            .collect()
    } else {
        Vec::new()
    };
    (0..cfg.replicas)
        .map(|r| {
            let cores: Vec<usize> = if slices.is_empty() {
                Vec::new()
            } else {
                // wrap when replicas outnumber cores (degenerate but legal)
                slices[r % slices.len()].clone()
            };
            // the batch worker runs chunk 0 of every kernel call itself, so
            // the pool only needs the remaining threads_per_worker - 1
            let pool_workers = cfg.threads_per_worker.saturating_sub(1);
            let pool = if private && pool_workers > 0 {
                Some(ThreadPool::pinned(pool_workers, &cores))
            } else {
                None
            };
            Arc::new(ReplicaState {
                busy: AtomicU64::new(0),
                workers: cfg.workers,
                cores,
                pool,
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn cfg(replicas: usize, pin: bool, threads: usize) -> ServerConfig {
        ServerConfig {
            replicas,
            pin_cores: pin,
            threads_per_worker: threads,
            workers: 1,
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            queue_cap: 0,
            mem_budget_bytes: 0,
        }
    }

    #[test]
    fn default_single_replica_builds_no_private_pool() {
        let reps = build_replicas(&cfg(1, false, 4));
        assert_eq!(reps.len(), 1);
        assert!(reps[0].pool.is_none(), "replicas=1 unpinned must keep the global pool");
        assert!(reps[0].cores.is_empty());
        reps[0].shutdown_pool(); // no-op
    }

    #[test]
    fn multi_replica_gets_private_pools_and_disjoint_cores() {
        let reps = build_replicas(&cfg(2, true, 2));
        assert_eq!(reps.len(), 2);
        for r in &reps {
            assert!(r.pool.is_some(), "replicas>1 must isolate kernel pools");
        }
        // core slices are disjoint when the host has >= 2 cores
        if threads::default_threads() >= 2 {
            assert!(reps[0].cores.iter().all(|c| !reps[1].cores.contains(c)));
            assert!(!reps[0].cores.is_empty() && !reps[1].cores.is_empty());
        }
        for r in &reps {
            r.shutdown_pool();
        }
    }

    #[test]
    fn single_kernel_thread_needs_no_pool_even_when_pinned() {
        // threads_per_worker=1 executes inline; pinning still records cores
        let reps = build_replicas(&cfg(2, true, 1));
        assert!(reps.iter().all(|r| r.pool.is_none()));
        assert!(reps.iter().all(|r| !r.cores.is_empty()));
    }

    #[test]
    fn occupancy_counts_enter_leave() {
        let reps = build_replicas(&cfg(1, false, 1));
        assert_eq!(reps[0].busy(), 0);
        reps[0].enter();
        reps[0].enter();
        assert_eq!(reps[0].busy(), 2);
        reps[0].leave();
        assert_eq!(reps[0].busy(), 1);
        reps[0].leave();
        assert_eq!(reps[0].busy(), 0);
    }
}

//! Serving coordinator: request router, dynamic batcher, worker pool.
//!
//! The paper's DeepliteRT is a standalone engine; this layer is the L3
//! system that makes it deployable the way vLLM's router makes a model
//! servable: callers submit single images, the batcher coalesces them into
//! one NHWC batch (up to `max_batch`, waiting at most `max_wait`), a worker
//! pool runs the compiled model, and per-request outputs are split back
//! out. Metrics track queueing + execution latency.
//!
//! Admission control: with [`ServerConfig::queue_cap`] set (the `dlrt
//! serve` gateway always sets it; `0` means unbounded for direct library
//! use), [`InferenceServer::try_submit`] refuses work instead of queueing
//! unboundedly — the HTTP gateway maps refusals to 429/503. When a memory
//! budget is set, the effective `max_batch` and queue bound are derived
//! from the plan's arena footprint ([`crate::exec::planner::ExecPlan`])
//! rather than trusting the configured values blindly.
//!
//! Shutdown has two modes: [`InferenceServer::drain`] (graceful — refuse
//! new work, finish everything queued) and drop (hard — pending requests
//! get an explicit "server stopping" error so no client `recv` ever hangs).

pub mod batcher;
pub mod metrics;
pub mod postproc;
pub mod replica;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::dlrt::tensor::Tensor;
use crate::exec::{CompiledModel, Executor};

pub use metrics::MetricsSnapshot;
pub use replica::ReplicaState;

#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// batch workers *per replica*
    pub workers: usize,
    pub max_batch: usize,
    pub max_wait: Duration,
    /// kernel-level threads per worker (keep replicas*workers*threads <=
    /// cores)
    pub threads_per_worker: usize,
    /// max requests waiting in the queue; 0 = derive from the memory
    /// budget when one is set, else unbounded
    pub queue_cap: usize,
    /// arena memory budget in bytes across all workers of all replicas;
    /// 0 = no budget. Clamps the effective `max_batch` (each worker owns
    /// one arena of `arena_bytes(max_batch)`) and sizes the queue bound.
    pub mem_budget_bytes: usize,
    /// independent executor pools per model; replicas share the queue but
    /// never share kernel threads, so one model's replicas (and different
    /// models') stop contending for the global pool. 1 (the default)
    /// preserves the original single-pool behavior exactly.
    pub replicas: usize,
    /// pin each replica's threads to a disjoint core slice (Linux only;
    /// best effort elsewhere)
    pub pin_cores: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            workers: 1,
            max_batch: 4,
            max_wait: Duration::from_millis(2),
            threads_per_worker: 1,
            queue_cap: 0,
            mem_budget_bytes: 0,
            replicas: 1,
            pin_cores: false,
        }
    }
}

/// Why [`InferenceServer::try_submit`] refused a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The bounded queue is full — shed load (HTTP 429).
    QueueFull { cap: usize },
    /// The server is draining or stopped (HTTP 503).
    Stopping,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull { cap } => write!(f, "queue full (cap {cap})"),
            SubmitError::Stopping => write!(f, "server stopping"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Typed marker delivered through a request's result channel when a hard
/// stop discards it mid-queue — callers map it to 503 by downcast
/// (`err.is::<ServerStopping>()`) instead of string-matching messages.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServerStopping;

impl std::fmt::Display for ServerStopping {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "server stopping")
    }
}

impl std::error::Error for ServerStopping {}

/// Per-request result delivered through the coordinator's channel: the
/// model outputs plus the timing/batching metadata the gateway's access
/// log and trace spans report.
#[derive(Debug)]
pub struct InferReply {
    pub outputs: Vec<Tensor>,
    /// Position of this request inside the executed batch.
    pub batch_index: usize,
    pub batch_size: usize,
    /// Time spent queued before batch assembly, microseconds.
    pub queue_us: u64,
    /// Wall time of the batch's plan execution, microseconds.
    pub exec_us: u64,
}

/// Borrowed view of one request's share of a completed batch. `outputs`
/// are the *batched* tensors (`[B, ...]`); the receiver slices sample
/// `batch_index` out itself — the event-loop gateway renders the raw wire
/// body directly from the batched slice, one copy total, instead of
/// materializing per-request tensors first.
pub struct ReplyRef<'a> {
    pub outputs: &'a [Tensor],
    pub batch_index: usize,
    pub batch_size: usize,
    pub queue_us: u64,
    pub exec_us: u64,
}

/// What a [`ReplyCallback`] is invoked with, exactly once per request.
pub enum ReplyOutcome<'a> {
    Ok(ReplyRef<'a>),
    /// batch execution failed
    Err(&'a anyhow::Error),
    /// the server hard-stopped before the request ran (maps to 503)
    Stopping,
}

/// Completion callback for [`InferenceServer::try_submit_cb`]. Runs on the
/// batch worker thread right after execution — keep it cheap (render +
/// hand off); it must never block on the peer.
pub type ReplyCallback = Box<dyn FnOnce(ReplyOutcome<'_>) + Send>;

/// How a request's result gets back to its submitter.
enum Responder {
    /// `try_submit`: per-request outputs sliced and sent over a channel
    Channel(mpsc::Sender<Result<InferReply>>),
    /// `try_submit_cb`: invoked on the worker with the batched outputs
    Callback(ReplyCallback),
}

struct Request {
    input: Tensor, // [1, H, W, C]
    enqueued: Instant,
    resp: Responder,
}

struct Shared {
    queue: Mutex<Vec<Request>>,
    cv: Condvar,
    /// graceful: refuse new work, finish the queue, then workers exit
    draining: AtomicBool,
    /// hard: error out pending requests and exit now
    stop: AtomicBool,
    metrics: metrics::Metrics,
    cfg: ServerConfig,
}

/// Handle for a running inference server.
pub struct InferenceServer {
    shared: Arc<Shared>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    replicas: Vec<Arc<ReplicaState>>,
}

impl InferenceServer {
    pub fn start(model: Arc<CompiledModel>, cfg: ServerConfig) -> InferenceServer {
        // Warm the persistent kernel pool before accepting traffic so no
        // request — not even the first — pays thread-spawn latency.
        crate::util::threads::global();
        let mut cfg = cfg;
        cfg.workers = cfg.workers.max(1);
        cfg.max_batch = cfg.max_batch.max(1);
        cfg.replicas = cfg.replicas.max(1);
        let total_workers = cfg.workers * cfg.replicas;
        if cfg.mem_budget_bytes > 0 {
            // plan-aware batching: each worker owns an arena that scales
            // linearly with batch, so the largest batch the budget admits
            // is budget / total-workers / arena-bytes-per-item
            let per_worker = cfg.mem_budget_bytes / total_workers;
            let fit = model.plan.max_batch_for_budget(per_worker);
            if fit < cfg.max_batch {
                eprintln!(
                    "[coordinator] {}: max_batch clamped {} -> {} \
                     (arena {} B/item x {} workers vs {} B budget)",
                    model.graph.name,
                    cfg.max_batch,
                    fit,
                    model.plan.arena_bytes(1),
                    total_workers,
                    cfg.mem_budget_bytes
                );
                cfg.max_batch = fit;
            }
            if cfg.queue_cap == 0 {
                // queued requests hold their input tensors: bound the queue
                // so waiting work also respects the budget (floor of one
                // full round of batches so batching stays effective)
                let per_req = model.plan.input_bytes().max(1);
                cfg.queue_cap = (cfg.mem_budget_bytes / per_req)
                    .max(total_workers * cfg.max_batch)
                    .min(65_536);
            }
        }
        let shared = Arc::new(Shared {
            queue: Mutex::new(Vec::new()),
            cv: Condvar::new(),
            draining: AtomicBool::new(false),
            stop: AtomicBool::new(false),
            metrics: metrics::Metrics::default(),
            cfg,
        });
        let replicas = replica::build_replicas(&cfg);
        let mut handles = Vec::with_capacity(total_workers);
        for (r, state) in replicas.iter().enumerate() {
            for w in 0..cfg.workers {
                let shared = shared.clone();
                let model = model.clone();
                let state = state.clone();
                let handle = std::thread::Builder::new()
                    .name(format!("dlrt-batch-{r}.{w}"))
                    .spawn(move || {
                        state.install_on_current_thread();
                        worker_loop(&shared, &model, &state)
                    })
                    .expect("spawning batch worker");
                handles.push(handle);
            }
        }
        InferenceServer { shared, handles: Mutex::new(handles), replicas }
    }

    /// The effective configuration (after plan-aware clamping).
    pub fn config(&self) -> ServerConfig {
        self.shared.cfg
    }

    /// Requests currently waiting to be batched.
    pub fn queue_depth(&self) -> usize {
        self.shared.queue.lock().unwrap().len()
    }

    /// Submit one input if the server is accepting work and the queue has
    /// room; returns a receiver for its outputs + timing metadata.
    pub fn try_submit(
        &self,
        input: Tensor,
    ) -> std::result::Result<mpsc::Receiver<Result<InferReply>>, SubmitError> {
        let (tx, rx) = mpsc::channel();
        self.enqueue(input, Responder::Channel(tx))?;
        Ok(rx)
    }

    /// Submit one input with a completion callback instead of a channel.
    /// The callback runs on the batch worker thread with a borrowed view of
    /// the *batched* outputs ([`ReplyOutcome`]) — the event-loop gateway
    /// uses this to render responses without a per-request tensor copy and
    /// without parking a thread in `recv()`. On `Err` the callback was not
    /// (and will never be) invoked; the caller responds synchronously.
    pub fn try_submit_cb(
        &self,
        input: Tensor,
        cb: ReplyCallback,
    ) -> std::result::Result<(), SubmitError> {
        self.enqueue(input, Responder::Callback(cb))
    }

    fn enqueue(&self, input: Tensor, resp: Responder) -> std::result::Result<(), SubmitError> {
        {
            let mut q = self.shared.queue.lock().unwrap();
            // checked under the queue lock so a drain started after this
            // point still sees (and finishes) the request
            if self.shared.draining.load(Ordering::SeqCst)
                || self.shared.stop.load(Ordering::SeqCst)
            {
                return Err(SubmitError::Stopping);
            }
            let cap = self.shared.cfg.queue_cap;
            if cap > 0 && q.len() >= cap {
                return Err(SubmitError::QueueFull { cap });
            }
            q.push(Request { input, enqueued: Instant::now(), resp });
        }
        self.shared.cv.notify_one();
        Ok(())
    }

    /// `(busy workers, total workers)` per replica — the
    /// `dlrt_model_replica_occupancy` gauge.
    pub fn replica_occupancy(&self) -> Vec<(u64, usize)> {
        self.replicas.iter().map(|r| (r.busy(), r.workers)).collect()
    }

    /// Submit one input; returns a receiver for its outputs. Admission
    /// refusals are delivered through the channel as errors, so existing
    /// callers never block on a request that was not accepted.
    pub fn submit(&self, input: Tensor) -> mpsc::Receiver<Result<InferReply>> {
        match self.try_submit(input) {
            Ok(rx) => rx,
            Err(e) => {
                let (tx, rx) = mpsc::channel();
                let _ = tx.send(Err(anyhow!("request refused: {e}")));
                rx
            }
        }
    }

    /// Convenience: submit + wait, discarding the timing metadata.
    pub fn infer(&self, input: Tensor) -> Result<Vec<Tensor>> {
        self.submit(input)
            .recv()
            .map_err(|_| anyhow!("server dropped request"))?
            .map(|r| r.outputs)
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.metrics.snapshot()
    }

    /// Graceful shutdown: refuse new submissions, run everything already
    /// queued, then stop the workers. Safe to call from multiple handles
    /// (e.g. through an `Arc`) — later calls are no-ops.
    pub fn drain(&self) {
        {
            // set the flag under the queue lock: a worker that just saw
            // draining=false cannot reach cv.wait() until we release it,
            // so the notify below can never be lost
            let _q = self.shared.queue.lock().unwrap();
            self.shared.draining.store(true, Ordering::SeqCst);
        }
        self.shared.cv.notify_all();
        let handles: Vec<_> = self.handles.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
        // batch workers are gone; their private kernel pools can now stop
        // (idempotent — a second drain/drop finds them already down)
        for r in &self.replicas {
            r.shutdown_pool();
        }
    }

    /// Graceful shutdown by value (see [`InferenceServer::drain`]).
    pub fn shutdown(self) {
        self.drain();
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        // hard stop: pending requests get an explicit "server stopping"
        // error (from the workers' final queue sweep) instead of hanging
        {
            // under the queue lock so the notify below cannot be lost (see
            // `drain`)
            let _q = self.shared.queue.lock().unwrap();
            self.shared.stop.store(true, Ordering::SeqCst);
            self.shared.draining.store(true, Ordering::SeqCst);
        }
        self.shared.cv.notify_all();
        let handles: Vec<_> = self.handles.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
        for r in &self.replicas {
            r.shutdown_pool();
        }
    }
}

fn worker_loop(shared: &Shared, model: &CompiledModel, state: &ReplicaState) {
    // Each coordinator worker owns its executor — and through it a long-lived
    // handle on its replica's kernel pool (the global pool for unpinned
    // single-replica servers) — for its whole lifetime. All workers run the
    // one execution plan compiled into the shared model; each keeps a
    // private arena plus reusable output tensors, so at steady state a
    // batch execution allocates nothing inside the executor.
    let mut exec = Executor::new(shared.cfg.threads_per_worker);
    // per-instruction rings feed the per-op-class Prometheus counters;
    // preallocated here (plan size is fixed) so the request path stays
    // allocation-free
    exec.enable_profiling(&model.plan);
    let mut outputs: Vec<Tensor> = Vec::new();
    loop {
        let batch = batcher::collect_batch(shared);
        let Some(batch) = batch else { return }; // stop signal
        // Queue latency ends at dequeue: record it per request here, before
        // executing, so the batch's exec time is never subtracted from late
        // joiners (which under-reported queueing as clamped negatives).
        let dequeued = Instant::now();
        let queue_ms: Vec<f64> = batch
            .iter()
            .map(|r| dequeued.saturating_duration_since(r.enqueued).as_secs_f64() * 1e3)
            .collect();
        let n = batch.len();
        state.enter();
        let t0 = Instant::now();
        // catch panics so one poisoned batch cannot kill the (possibly
        // only) worker and leave queued callers blocked in recv() forever
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let stacked =
                batcher::stack_inputs(&batch.iter().map(|r| &r.input).collect::<Vec<_>>())?;
            exec.run_into(model, &stacked, &mut outputs)
        }));
        let exec_ms = t0.elapsed().as_secs_f64() * 1e3;
        match result {
            Ok(Ok(())) => {
                let exec_us = (exec_ms * 1e3) as u64;
                for (bi, req) in batch.into_iter().enumerate() {
                    let queue_us = (queue_ms[bi] * 1e3) as u64;
                    shared.metrics.observe(queue_ms[bi], exec_ms, n);
                    match req.resp {
                        Responder::Channel(tx) => {
                            let per: Result<InferReply> = outputs
                                .iter()
                                .map(|o| batcher::slice_batch(o, bi))
                                .collect::<Result<Vec<Tensor>>>()
                                .map(|outputs| InferReply {
                                    outputs,
                                    batch_index: bi,
                                    batch_size: n,
                                    queue_us,
                                    exec_us,
                                });
                            let _ = tx.send(per);
                        }
                        Responder::Callback(cb) => cb(ReplyOutcome::Ok(ReplyRef {
                            outputs: &outputs,
                            batch_index: bi,
                            batch_size: n,
                            queue_us,
                            exec_us,
                        })),
                    }
                }
                // fold this batch's per-op-class instruction time into the
                // model's metrics (rendered by /metrics)
                if let Some(p) = exec.profiler_mut() {
                    shared.metrics.observe_class_seconds(&p.drain_class_totals());
                }
            }
            Ok(Err(e)) => {
                shared.metrics.observe_errors(n);
                for req in batch {
                    match req.resp {
                        Responder::Channel(tx) => {
                            let _ = tx.send(Err(anyhow!("{:#}", e)));
                        }
                        Responder::Callback(cb) => cb(ReplyOutcome::Err(&e)),
                    }
                }
            }
            Err(_panic) => {
                // executor/scratch state is suspect after an unwind:
                // rebuild them, answer the batch, keep serving
                exec = Executor::new(shared.cfg.threads_per_worker);
                exec.enable_profiling(&model.plan);
                outputs = Vec::new();
                shared.metrics.observe_errors(n);
                let err = anyhow!("worker panicked during batch execution");
                for req in batch {
                    match req.resp {
                        Responder::Channel(tx) => {
                            let _ = tx.send(Err(anyhow!("worker panicked during batch execution")));
                        }
                        Responder::Callback(cb) => cb(ReplyOutcome::Err(&err)),
                    }
                }
            }
        }
        state.leave();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile_graph, EngineChoice};
    use crate::models::tiny_test_graph;

    fn tiny_model() -> Arc<CompiledModel> {
        let g = tiny_test_graph(false);
        Arc::new(compile_graph(&g, EngineChoice::Auto).unwrap())
    }

    fn server(cfg: ServerConfig) -> InferenceServer {
        InferenceServer::start(tiny_model(), cfg)
    }

    #[test]
    fn single_request_roundtrip() {
        let srv = server(ServerConfig::default());
        let x = Tensor::zeros(vec![1, 8, 8, 3]);
        let outs = srv.infer(x).unwrap();
        assert_eq!(outs[0].shape, vec![1, 4]);
        let m = srv.metrics();
        assert_eq!(m.completed, 1);
        srv.shutdown();
    }

    #[test]
    fn concurrent_requests_all_answered() {
        let srv = server(ServerConfig {
            workers: 2,
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            ..ServerConfig::default()
        });
        let rxs: Vec<_> = (0..16)
            .map(|i| {
                let mut x = Tensor::zeros(vec![1, 8, 8, 3]);
                x.data[0] = i as f32;
                srv.submit(x)
            })
            .collect();
        for rx in rxs {
            let rep = rx.recv().unwrap().unwrap();
            assert_eq!(rep.outputs[0].shape, vec![1, 4]);
            assert!(rep.batch_index < rep.batch_size);
        }
        let m = srv.metrics();
        assert_eq!(m.completed, 16);
        assert!(m.mean_batch >= 1.0);
        // exec-time histogram and per-op-class counters saw the traffic
        assert_eq!(m.exec_hist.count, 16);
        assert!(m.class_exec_s.iter().sum::<f64>() > 0.0);
        srv.shutdown();
    }

    #[test]
    fn batched_equals_unbatched() {
        let model = tiny_model();
        let mut exec = Executor::new(1);
        let mut x = Tensor::zeros(vec![1, 8, 8, 3]);
        for (i, v) in x.data.iter_mut().enumerate() {
            *v = (i % 7) as f32 * 0.2;
        }
        let direct = exec.run(&model, &x).unwrap();

        let srv = InferenceServer::start(model, ServerConfig {
            workers: 1,
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            ..ServerConfig::default()
        });
        // submit several identical requests so they batch together
        let rxs: Vec<_> = (0..6).map(|_| srv.submit(x.clone())).collect();
        for rx in rxs {
            let rep = rx.recv().unwrap().unwrap();
            assert_eq!(rep.outputs[0].data, direct[0].data);
        }
        srv.shutdown();
    }

    #[test]
    fn shutdown_drains_cleanly() {
        let srv = server(ServerConfig::default());
        srv.shutdown(); // no panic, no hang
    }

    #[test]
    fn queue_cap_rejects_overflow() {
        // one worker holding a wide batching window: the first request sits
        // in the (cap-1) queue, so the second is refused at admission
        let srv = server(ServerConfig {
            workers: 1,
            max_batch: 8,
            max_wait: Duration::from_millis(500),
            queue_cap: 1,
            ..ServerConfig::default()
        });
        let rx1 = srv.try_submit(Tensor::zeros(vec![1, 8, 8, 3])).unwrap();
        // give the worker time to open the batching window (request stays
        // queued until max_batch or the deadline)
        std::thread::sleep(Duration::from_millis(50));
        match srv.try_submit(Tensor::zeros(vec![1, 8, 8, 3])) {
            Err(SubmitError::QueueFull { cap: 1 }) => {}
            other => panic!("expected QueueFull, got {other:?}"),
        }
        // the accepted request still completes
        assert!(rx1.recv().unwrap().is_ok());
        srv.shutdown();
    }

    #[test]
    fn drain_finishes_queued_requests() {
        let srv = server(ServerConfig {
            workers: 1,
            max_batch: 64,
            max_wait: Duration::from_millis(500),
            ..ServerConfig::default()
        });
        let rxs: Vec<_> =
            (0..5).map(|_| srv.try_submit(Tensor::zeros(vec![1, 8, 8, 3])).unwrap()).collect();
        // drain long before the 500ms window closes: queued requests must
        // run, not wait out the window or get dropped
        let t0 = Instant::now();
        srv.shutdown();
        assert!(t0.elapsed() < Duration::from_millis(400), "drain waited out the window");
        for rx in rxs {
            assert!(rx.recv().unwrap().is_ok());
        }
    }

    #[test]
    fn submit_after_drain_is_refused() {
        let srv = server(ServerConfig::default());
        srv.drain();
        match srv.try_submit(Tensor::zeros(vec![1, 8, 8, 3])) {
            Err(SubmitError::Stopping) => {}
            other => panic!("expected Stopping, got {other:?}"),
        }
        let res = srv.infer(Tensor::zeros(vec![1, 8, 8, 3]));
        assert!(res.is_err());
    }

    #[test]
    fn drop_errors_queued_requests_explicitly() {
        let srv = server(ServerConfig {
            workers: 1,
            max_batch: 64,
            max_wait: Duration::from_millis(500),
            ..ServerConfig::default()
        });
        let rxs: Vec<_> =
            (0..3).map(|_| srv.try_submit(Tensor::zeros(vec![1, 8, 8, 3])).unwrap()).collect();
        drop(srv); // hard stop
        for rx in rxs {
            match rx.recv().unwrap() {
                Err(e) => assert!(e.is::<ServerStopping>(), "got {e:#}"),
                Ok(_) => {} // a batch already in flight may legitimately finish
            }
        }
    }

    #[test]
    fn mem_budget_clamps_batch_and_bounds_queue() {
        let model = tiny_model();
        let budget = 2 * model.plan.arena_bytes(1);
        let srv = InferenceServer::start(model, ServerConfig {
            workers: 1,
            max_batch: 16,
            max_wait: Duration::from_millis(1),
            mem_budget_bytes: budget,
            ..ServerConfig::default()
        });
        let eff = srv.config();
        assert_eq!(eff.max_batch, 2, "budget for two arena items admits batch 2");
        assert!(eff.queue_cap > 0, "budget must bound the queue");
        // still serves correctly at the clamped batch
        let outs = srv.infer(Tensor::zeros(vec![1, 8, 8, 3])).unwrap();
        assert_eq!(outs[0].shape, vec![1, 4]);
        srv.shutdown();
    }
}

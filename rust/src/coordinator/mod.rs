//! Serving coordinator: request router, dynamic batcher, worker pool.
//!
//! The paper's DeepliteRT is a standalone engine; this layer is the L3
//! system that makes it deployable the way vLLM's router makes a model
//! servable: callers submit single images, the batcher coalesces them into
//! one NHWC batch (up to `max_batch`, waiting at most `max_wait`), a worker
//! pool runs the compiled model, and per-request outputs are split back
//! out. Metrics track queueing + execution latency.

pub mod batcher;
pub mod metrics;
pub mod postproc;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::dlrt::tensor::Tensor;
use crate::exec::{CompiledModel, Executor};

pub use metrics::MetricsSnapshot;

#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    pub workers: usize,
    pub max_batch: usize,
    pub max_wait: Duration,
    /// kernel-level threads per worker (keep workers*threads <= cores)
    pub threads_per_worker: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            workers: 1,
            max_batch: 4,
            max_wait: Duration::from_millis(2),
            threads_per_worker: 1,
        }
    }
}

struct Request {
    input: Tensor, // [1, H, W, C]
    enqueued: Instant,
    tx: mpsc::Sender<Result<Vec<Tensor>>>,
}

struct Shared {
    queue: Mutex<Vec<Request>>,
    cv: Condvar,
    stop: AtomicBool,
    metrics: metrics::Metrics,
    cfg: ServerConfig,
}

/// Handle for a running inference server.
pub struct InferenceServer {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl InferenceServer {
    pub fn start(model: Arc<CompiledModel>, cfg: ServerConfig) -> InferenceServer {
        // Warm the persistent kernel pool before accepting traffic so no
        // request — not even the first — pays thread-spawn latency.
        crate::util::threads::global();
        let shared = Arc::new(Shared {
            queue: Mutex::new(Vec::new()),
            cv: Condvar::new(),
            stop: AtomicBool::new(false),
            metrics: metrics::Metrics::default(),
            cfg,
        });
        let handles = (0..cfg.workers.max(1))
            .map(|_| {
                let shared = shared.clone();
                let model = model.clone();
                std::thread::spawn(move || worker_loop(&shared, &model))
            })
            .collect();
        InferenceServer { shared, handles }
    }

    /// Submit one input; returns a receiver for its outputs.
    pub fn submit(&self, input: Tensor) -> mpsc::Receiver<Result<Vec<Tensor>>> {
        let (tx, rx) = mpsc::channel();
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push(Request { input, enqueued: Instant::now(), tx });
        }
        self.shared.cv.notify_one();
        rx
    }

    /// Convenience: submit + wait.
    pub fn infer(&self, input: Tensor) -> Result<Vec<Tensor>> {
        self.submit(input)
            .recv()
            .map_err(|_| anyhow!("server dropped request"))?
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.metrics.snapshot()
    }

    pub fn shutdown(mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared, model: &CompiledModel) {
    // Each coordinator worker owns its executor — and through it a long-lived
    // handle on the persistent kernel pool — for its whole lifetime. All
    // workers run the one execution plan compiled into the shared model;
    // each keeps a private arena plus reusable output tensors, so at steady
    // state a batch execution allocates nothing inside the executor.
    let mut exec = Executor::new(shared.cfg.threads_per_worker);
    let mut outputs: Vec<Tensor> = Vec::new();
    loop {
        let batch = batcher::collect_batch(shared);
        let Some(batch) = batch else { return }; // stop signal
        // Queue latency ends at dequeue: record it per request here, before
        // executing, so the batch's exec time is never subtracted from late
        // joiners (which under-reported queueing as clamped negatives).
        let dequeued = Instant::now();
        let queue_ms: Vec<f64> = batch
            .iter()
            .map(|r| dequeued.saturating_duration_since(r.enqueued).as_secs_f64() * 1e3)
            .collect();
        let n = batch.len();
        let stacked = batcher::stack_inputs(&batch.iter().map(|r| &r.input).collect::<Vec<_>>());
        let t0 = Instant::now();
        let result = stacked.and_then(|x| exec.run_into(model, &x, &mut outputs));
        let exec_ms = t0.elapsed().as_secs_f64() * 1e3;
        match result {
            Ok(()) => {
                for (bi, req) in batch.into_iter().enumerate() {
                    let per: Result<Vec<Tensor>> =
                        outputs.iter().map(|o| batcher::slice_batch(o, bi)).collect();
                    shared.metrics.observe(queue_ms[bi], exec_ms, n);
                    let _ = req.tx.send(per);
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for req in batch {
                    let _ = req.tx.send(Err(anyhow!("{msg}")));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile_graph, EngineChoice};
    use crate::models::tiny_test_graph;

    fn server(cfg: ServerConfig) -> InferenceServer {
        let g = tiny_test_graph(false);
        let m = Arc::new(compile_graph(&g, EngineChoice::Auto).unwrap());
        InferenceServer::start(m, cfg)
    }

    #[test]
    fn single_request_roundtrip() {
        let srv = server(ServerConfig::default());
        let x = Tensor::zeros(vec![1, 8, 8, 3]);
        let outs = srv.infer(x).unwrap();
        assert_eq!(outs[0].shape, vec![1, 4]);
        let m = srv.metrics();
        assert_eq!(m.completed, 1);
        srv.shutdown();
    }

    #[test]
    fn concurrent_requests_all_answered() {
        let srv = server(ServerConfig {
            workers: 2,
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            threads_per_worker: 1,
        });
        let rxs: Vec<_> = (0..16)
            .map(|i| {
                let mut x = Tensor::zeros(vec![1, 8, 8, 3]);
                x.data[0] = i as f32;
                srv.submit(x)
            })
            .collect();
        for rx in rxs {
            let outs = rx.recv().unwrap().unwrap();
            assert_eq!(outs[0].shape, vec![1, 4]);
        }
        let m = srv.metrics();
        assert_eq!(m.completed, 16);
        assert!(m.mean_batch >= 1.0);
        srv.shutdown();
    }

    #[test]
    fn batched_equals_unbatched() {
        let g = tiny_test_graph(false);
        let model = Arc::new(compile_graph(&g, EngineChoice::Auto).unwrap());
        let mut exec = Executor::new(1);
        let mut x = Tensor::zeros(vec![1, 8, 8, 3]);
        for (i, v) in x.data.iter_mut().enumerate() {
            *v = (i % 7) as f32 * 0.2;
        }
        let direct = exec.run(&model, &x).unwrap();

        let srv = InferenceServer::start(model, ServerConfig {
            workers: 1,
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            threads_per_worker: 1,
        });
        // submit several identical requests so they batch together
        let rxs: Vec<_> = (0..6).map(|_| srv.submit(x.clone())).collect();
        for rx in rxs {
            let outs = rx.recv().unwrap().unwrap();
            assert_eq!(outs[0].data, direct[0].data);
        }
        srv.shutdown();
    }

    #[test]
    fn shutdown_drains_cleanly() {
        let srv = server(ServerConfig::default());
        srv.shutdown(); // no panic, no hang
    }
}

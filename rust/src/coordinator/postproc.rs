//! Detection postprocessing: YOLOv5 decode + class-wise NMS.
//!
//! Runs in the coordinator (not the model graph), as in the paper's
//! runtime: the `.dlrt` model emits raw per-scale maps; this decodes them
//! into boxes with the Ultralytics v5 parameterization:
//!
//! ```text
//!   xy = (2·σ(t_xy) − 0.5 + grid) · stride
//!   wh = (2·σ(t_wh))² · anchor
//! ```

use crate::dlrt::tensor::Tensor;
use crate::kernels::elementwise::sigmoid_scalar;

/// Default YOLOv5 COCO anchors (pixels, per scale P3/P4/P5).
pub const DEFAULT_ANCHORS: [[(f32, f32); 3]; 3] = [
    [(10.0, 13.0), (16.0, 30.0), (33.0, 23.0)],
    [(30.0, 61.0), (62.0, 45.0), (59.0, 119.0)],
    [(116.0, 90.0), (156.0, 198.0), (373.0, 326.0)],
];

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Detection {
    /// xyxy in input-image pixels
    pub bbox: [f32; 4],
    pub class_id: usize,
    pub score: f32,
}

/// Decode one batch element from the 3 raw head maps.
///
/// `maps[i]`: [1, H_i, W_i, na*(5+nc)]; `strides` typically [8, 16, 32].
pub fn decode_yolo(
    maps: &[Tensor],
    num_classes: usize,
    strides: &[usize],
    anchors: &[[(f32, f32); 3]],
    conf_thresh: f32,
) -> Vec<Detection> {
    let mut dets = Vec::new();
    let no = 5 + num_classes;
    for (si, map) in maps.iter().enumerate() {
        let (_, h, w, cdim) = map.nhwc();
        let na = cdim / no;
        let stride = strides[si] as f32;
        for gy in 0..h {
            for gx in 0..w {
                for a in 0..na {
                    let base = ((gy * w + gx) * cdim) + a * no;
                    let obj = sigmoid_scalar(map.data[base + 4]);
                    if obj < conf_thresh {
                        continue;
                    }
                    // best class
                    let (mut best_c, mut best_p) = (0usize, f32::MIN);
                    for c in 0..num_classes {
                        let p = map.data[base + 5 + c];
                        if p > best_p {
                            best_p = p;
                            best_c = c;
                        }
                    }
                    let score = obj * sigmoid_scalar(best_p);
                    if score < conf_thresh {
                        continue;
                    }
                    let tx = sigmoid_scalar(map.data[base]);
                    let ty = sigmoid_scalar(map.data[base + 1]);
                    let tw = sigmoid_scalar(map.data[base + 2]);
                    let th = sigmoid_scalar(map.data[base + 3]);
                    let cx = (2.0 * tx - 0.5 + gx as f32) * stride;
                    let cy = (2.0 * ty - 0.5 + gy as f32) * stride;
                    let (aw, ah) = anchors[si][a.min(2)];
                    let bw = (2.0 * tw) * (2.0 * tw) * aw;
                    let bh = (2.0 * th) * (2.0 * th) * ah;
                    dets.push(Detection {
                        bbox: [cx - bw / 2.0, cy - bh / 2.0, cx + bw / 2.0, cy + bh / 2.0],
                        class_id: best_c,
                        score,
                    });
                }
            }
        }
    }
    dets
}

pub fn iou(a: &[f32; 4], b: &[f32; 4]) -> f32 {
    let x0 = a[0].max(b[0]);
    let y0 = a[1].max(b[1]);
    let x1 = a[2].min(b[2]);
    let y1 = a[3].min(b[3]);
    let inter = (x1 - x0).max(0.0) * (y1 - y0).max(0.0);
    let area_a = (a[2] - a[0]).max(0.0) * (a[3] - a[1]).max(0.0);
    let area_b = (b[2] - b[0]).max(0.0) * (b[3] - b[1]).max(0.0);
    let union = area_a + area_b - inter;
    if union > 0.0 {
        inter / union
    } else {
        0.0
    }
}

/// Greedy class-wise non-maximum suppression.
pub fn nms(mut dets: Vec<Detection>, iou_thresh: f32) -> Vec<Detection> {
    dets.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
    let mut keep: Vec<Detection> = Vec::new();
    'outer: for d in dets {
        for k in &keep {
            if k.class_id == d.class_id && iou(&k.bbox, &d.bbox) > iou_thresh {
                continue 'outer;
            }
        }
        keep.push(d);
    }
    keep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iou_cases() {
        assert_eq!(iou(&[0.0, 0.0, 2.0, 2.0], &[0.0, 0.0, 2.0, 2.0]), 1.0);
        assert_eq!(iou(&[0.0, 0.0, 1.0, 1.0], &[2.0, 2.0, 3.0, 3.0]), 0.0);
        let v = iou(&[0.0, 0.0, 2.0, 2.0], &[1.0, 1.0, 3.0, 3.0]);
        assert!((v - 1.0 / 7.0).abs() < 1e-6);
    }

    #[test]
    fn nms_suppresses_overlaps_keeps_classes() {
        let dets = vec![
            Detection { bbox: [0.0, 0.0, 10.0, 10.0], class_id: 0, score: 0.9 },
            Detection { bbox: [1.0, 1.0, 11.0, 11.0], class_id: 0, score: 0.8 }, // suppressed
            Detection { bbox: [1.0, 1.0, 11.0, 11.0], class_id: 1, score: 0.7 }, // other class
            Detection { bbox: [50.0, 50.0, 60.0, 60.0], class_id: 0, score: 0.6 },
        ];
        let kept = nms(dets, 0.5);
        assert_eq!(kept.len(), 3);
        assert!(kept.iter().any(|d| d.class_id == 1));
    }

    #[test]
    fn decode_finds_planted_object() {
        // one 8x8 map, 1 anchor, 1 class; plant a confident object at (3,4)
        let num_classes = 1;
        let no = 6;
        let mut map = Tensor::zeros(vec![1, 8, 8, no]);
        for v in map.data.iter_mut() {
            *v = -10.0; // sigmoid ~ 0 everywhere
        }
        let base = (3 * 8 + 4) * no;
        map.data[base] = 0.0;       // tx: σ=0.5 → centered
        map.data[base + 1] = 0.0;
        map.data[base + 2] = 0.0;   // tw: (2·0.5)² = 1 → bw = anchor w
        map.data[base + 3] = 0.0;
        map.data[base + 4] = 8.0;   // obj ≈ 1
        map.data[base + 5] = 8.0;   // class ≈ 1
        let anchors = [[(16.0, 16.0); 3]];
        let dets = decode_yolo(&[map], num_classes, &[8], &anchors, 0.3);
        assert_eq!(dets.len(), 1);
        let d = dets[0];
        // center = (gx + 0.5) * 8 = 36, (gy + 0.5) * 8 = 28
        assert!((d.bbox[0] - (36.0 - 8.0)).abs() < 1e-3);
        assert!((d.bbox[1] - (28.0 - 8.0)).abs() < 1e-3);
        assert!(d.score > 0.9);
    }
}

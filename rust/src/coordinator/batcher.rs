//! Dynamic batching policy + batch assembly helpers.
//!
//! Policy: the worker blocks until at least one request is queued, then
//! keeps the window open until either `max_batch` requests arrived or the
//! oldest request has waited `max_wait`. This trades a bounded additional
//! queueing delay for GEMM efficiency (bigger `rows` amortizes the packed
//! weight streaming), the same trade serving systems make for LLM decode.

use std::sync::atomic::Ordering;
use std::time::Instant;

use anyhow::{bail, Result};

use super::{ReplyOutcome, Request, Responder, Shared};
use crate::dlrt::tensor::Tensor;

/// Block until a batch is available; `None` means the worker should exit.
///
/// Shutdown contract: on **drain** (graceful) the queue is run to empty —
/// the batching window is skipped so queued requests don't wait out
/// `max_wait` — and `None` is returned only once the queue is empty. On
/// **stop** (hard) every pending request is answered with an explicit
/// "server stopping" error before `None`, so no client `recv` ever hangs.
pub(super) fn collect_batch(shared: &Shared) -> Option<Vec<Request>> {
    let mut q = shared.queue.lock().unwrap();
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            fail_pending(&mut q);
            return None;
        }
        if !q.is_empty() {
            break;
        }
        if shared.draining.load(Ordering::SeqCst) {
            return None; // drained: queue empty, no new submissions
        }
        q = shared.cv.wait(q).unwrap();
    }
    // window: oldest request anchors the deadline (skipped while draining —
    // latency no longer matters, only finishing the queue does)
    if !shared.draining.load(Ordering::SeqCst) {
        let deadline = q[0].enqueued + shared.cfg.max_wait;
        while q.len() < shared.cfg.max_batch {
            if shared.stop.load(Ordering::SeqCst) || shared.draining.load(Ordering::SeqCst) {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (nq, timeout) = shared.cv.wait_timeout(q, deadline - now).unwrap();
            q = nq;
            if timeout.timed_out() {
                break;
            }
        }
    }
    if shared.stop.load(Ordering::SeqCst) {
        fail_pending(&mut q);
        return None;
    }
    let take = q.len().min(shared.cfg.max_batch);
    Some(q.drain(..take).collect())
}

/// Hard stop: answer every queued request with an explicit typed error.
fn fail_pending(q: &mut Vec<Request>) {
    for r in q.drain(..) {
        match r.resp {
            Responder::Channel(tx) => {
                let _ = tx.send(Err(anyhow::Error::new(super::ServerStopping)));
            }
            Responder::Callback(cb) => cb(ReplyOutcome::Stopping),
        }
    }
}

/// Stack [1,H,W,C] inputs into one [B,H,W,C] tensor.
pub fn stack_inputs(inputs: &[&Tensor]) -> Result<Tensor> {
    let first = inputs.first().ok_or_else(|| anyhow::anyhow!("empty batch"))?;
    if first.shape.len() != 4 || first.shape[0] != 1 {
        bail!("batcher expects [1,H,W,C] inputs, got {:?}", first.shape);
    }
    let mut data = Vec::with_capacity(first.numel() * inputs.len());
    for t in inputs {
        if t.shape != first.shape {
            bail!("mixed shapes in batch: {:?} vs {:?}", t.shape, first.shape);
        }
        data.extend_from_slice(&t.data);
    }
    let mut shape = first.shape.clone();
    shape[0] = inputs.len();
    Tensor::new(shape, data)
}

/// Extract sample `i` of a batched output as a batch-1 tensor.
pub fn slice_batch(t: &Tensor, i: usize) -> Result<Tensor> {
    if t.shape.is_empty() {
        bail!("scalar output cannot be sliced");
    }
    let b = t.shape[0];
    if i >= b {
        bail!("batch index {i} out of {b}");
    }
    let per: usize = t.shape[1..].iter().product();
    let mut shape = t.shape.clone();
    shape[0] = 1;
    Tensor::new(shape, t.data[i * per..(i + 1) * per].to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stack_and_slice_roundtrip() {
        let mut a = Tensor::zeros(vec![1, 2, 2, 1]);
        let mut b = Tensor::zeros(vec![1, 2, 2, 1]);
        a.data.iter_mut().for_each(|v| *v = 1.0);
        b.data.iter_mut().for_each(|v| *v = 2.0);
        let stacked = stack_inputs(&[&a, &b]).unwrap();
        assert_eq!(stacked.shape, vec![2, 2, 2, 1]);
        assert_eq!(slice_batch(&stacked, 0).unwrap().data, a.data);
        assert_eq!(slice_batch(&stacked, 1).unwrap().data, b.data);
        assert!(slice_batch(&stacked, 2).is_err());
    }

    #[test]
    fn stack_rejects_mixed_shapes() {
        let a = Tensor::zeros(vec![1, 2, 2, 1]);
        let b = Tensor::zeros(vec![1, 3, 2, 1]);
        assert!(stack_inputs(&[&a, &b]).is_err());
        let c = Tensor::zeros(vec![2, 2, 2, 1]);
        assert!(stack_inputs(&[&c]).is_err());
    }
}

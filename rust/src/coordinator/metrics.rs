//! Serving metrics: queue/exec latency quantiles, batch sizes, throughput.
//!
//! Long-running servers must not grow without bound, so observations are
//! split into **monotonic counters** (completed, errors, batch-size sums —
//! exact over the server's whole life) and a **fixed-capacity ring** of the
//! most recent latency samples that the quantiles are computed over. A
//! server handling millions of requests holds the same few KB of metric
//! state as one handling a hundred.

use std::sync::Mutex;
use std::time::Instant;

/// Latency samples kept per series for quantile estimation.
pub const WINDOW_CAP: usize = 1024;

/// Fixed-capacity ring buffer of the most recent observations.
#[derive(Debug)]
struct Reservoir {
    buf: Vec<f64>,
    next: usize,
    cap: usize,
}

impl Reservoir {
    fn new(cap: usize) -> Reservoir {
        Reservoir { buf: Vec::new(), next: 0, cap: cap.max(1) }
    }

    fn push(&mut self, v: f64) {
        if self.buf.len() < self.cap {
            self.buf.push(v);
        } else {
            self.buf[self.next] = v;
        }
        self.next = (self.next + 1) % self.cap;
    }

    fn values(&self) -> &[f64] {
        &self.buf
    }
}

pub struct Metrics {
    inner: Mutex<Inner>,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::with_window(WINDOW_CAP)
    }
}

struct Inner {
    queue_ms: Reservoir,
    exec_ms: Reservoir,
    completed: u64,
    errors: u64,
    batch_size_sum: u64,
    started: Option<Instant>,
}

#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    /// Requests answered successfully (monotonic).
    pub completed: usize,
    /// Requests answered with an execution error (monotonic).
    pub errors: usize,
    pub p50_exec_ms: f64,
    pub p95_exec_ms: f64,
    pub p99_exec_ms: f64,
    pub p50_queue_ms: f64,
    pub p95_queue_ms: f64,
    pub p99_queue_ms: f64,
    pub mean_batch: f64,
    pub throughput_rps: f64,
    /// Samples currently in the quantile window (≤ [`WINDOW_CAP`]).
    pub window: usize,
}

impl Metrics {
    pub fn with_window(cap: usize) -> Metrics {
        Metrics {
            inner: Mutex::new(Inner {
                queue_ms: Reservoir::new(cap),
                exec_ms: Reservoir::new(cap),
                completed: 0,
                errors: 0,
                batch_size_sum: 0,
                started: None,
            }),
        }
    }

    pub fn observe(&self, queue_ms: f64, exec_ms: f64, batch: usize) {
        let mut m = self.inner.lock().unwrap();
        m.started.get_or_insert_with(Instant::now);
        m.queue_ms.push(queue_ms);
        m.exec_ms.push(exec_ms);
        m.completed += 1;
        m.batch_size_sum += batch as u64;
    }

    /// Record `n` requests answered with an execution error.
    pub fn observe_errors(&self, n: usize) {
        let mut m = self.inner.lock().unwrap();
        m.started.get_or_insert_with(Instant::now);
        m.errors += n as u64;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.lock().unwrap();
        if m.completed == 0 && m.errors == 0 {
            return MetricsSnapshot::default();
        }
        let elapsed = m.started.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
        MetricsSnapshot {
            completed: m.completed as usize,
            errors: m.errors as usize,
            p50_exec_ms: percentile(m.exec_ms.values(), 0.50),
            p95_exec_ms: percentile(m.exec_ms.values(), 0.95),
            p99_exec_ms: percentile(m.exec_ms.values(), 0.99),
            p50_queue_ms: percentile(m.queue_ms.values(), 0.50),
            p95_queue_ms: percentile(m.queue_ms.values(), 0.95),
            p99_queue_ms: percentile(m.queue_ms.values(), 0.99),
            mean_batch: if m.completed > 0 {
                m.batch_size_sum as f64 / m.completed as f64
            } else {
                0.0
            },
            throughput_rps: if elapsed > 0.0 { m.completed as f64 / elapsed } else { 0.0 },
            window: m.exec_ms.values().len(),
        }
    }
}

pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((v.len() as f64 - 1.0) * q).round() as usize;
    v[idx.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (0..=100).map(|v| v as f64).collect();
        assert_eq!(percentile(&xs, 0.5), 50.0);
        assert_eq!(percentile(&xs, 0.95), 95.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn snapshot_aggregates() {
        let m = Metrics::default();
        for i in 0..10 {
            m.observe(1.0, 2.0 + i as f64, 2);
        }
        let s = m.snapshot();
        assert_eq!(s.completed, 10);
        assert_eq!(s.errors, 0);
        assert_eq!(s.mean_batch, 2.0);
        assert!(s.p95_exec_ms >= s.p50_exec_ms);
        assert!(s.p99_exec_ms >= s.p95_exec_ms);
    }

    #[test]
    fn long_run_memory_is_bounded_but_counters_exact() {
        let m = Metrics::with_window(64);
        for i in 0..10_000 {
            m.observe(0.5, i as f64, 1);
        }
        m.observe_errors(3);
        let s = m.snapshot();
        assert_eq!(s.completed, 10_000);
        assert_eq!(s.errors, 3);
        assert_eq!(s.window, 64);
        // quantiles reflect the recent window, not ancient history
        assert!(s.p50_exec_ms >= (10_000 - 64) as f64);
        assert!(s.p99_exec_ms >= s.p50_exec_ms);
        {
            let inner = m.inner.lock().unwrap();
            assert!(inner.exec_ms.values().len() <= 64);
            assert!(inner.queue_ms.values().len() <= 64);
        }
    }

    #[test]
    fn reservoir_overwrites_oldest() {
        let mut r = Reservoir::new(4);
        for i in 0..6 {
            r.push(i as f64);
        }
        let mut vals = r.values().to_vec();
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(vals, vec![2.0, 3.0, 4.0, 5.0]);
    }
}

//! Serving metrics: queue/exec latency quantiles, fixed-bucket histograms,
//! batch sizes, throughput, per-op-class execution time.
//!
//! Long-running servers must not grow without bound, so observations are
//! split into **monotonic counters** (completed, errors, batch-size sums,
//! histogram buckets, per-class exec seconds — exact over the server's
//! whole life) and a **fixed-capacity ring** of the most recent latency
//! samples that the quantiles are computed over. A server handling
//! millions of requests holds the same few KB of metric state as one
//! handling a hundred.

use std::sync::Mutex;
use std::time::Instant;

use crate::obs::N_CLASSES;

/// Latency samples kept per series for quantile estimation.
pub const WINDOW_CAP: usize = 1024;

/// Fixed upper bounds (ms) of the latency histogram buckets; an implicit
/// `+Inf` bucket completes the series. Prometheus histogram convention:
/// exported counts are cumulative (`le=...`).
pub const HIST_BUCKETS_MS: [f64; 12] =
    [0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 1000.0];

/// Monotonic fixed-bucket latency histogram (per-bucket counts are stored
/// non-cumulative; [`Hist::snapshot`] renders the cumulative form).
#[derive(Debug, Default)]
struct Hist {
    counts: [u64; HIST_BUCKETS_MS.len()],
    /// Observations above the last bucket bound (the `+Inf` bucket).
    overflow: u64,
    sum_ms: f64,
}

impl Hist {
    fn observe(&mut self, v_ms: f64) {
        match HIST_BUCKETS_MS.iter().position(|&le| v_ms <= le) {
            Some(i) => self.counts[i] += 1,
            None => self.overflow += 1,
        }
        self.sum_ms += v_ms;
    }

    fn snapshot(&self) -> HistSnapshot {
        let mut cumulative = Vec::with_capacity(HIST_BUCKETS_MS.len());
        let mut running = 0u64;
        for &c in &self.counts {
            running += c;
            cumulative.push(running);
        }
        HistSnapshot { cumulative, sum_ms: self.sum_ms, count: running + self.overflow }
    }
}

/// Cumulative view of a [`Hist`], aligned with [`HIST_BUCKETS_MS`];
/// `count` includes the `+Inf` overflow bucket.
#[derive(Clone, Debug, Default)]
pub struct HistSnapshot {
    pub cumulative: Vec<u64>,
    pub sum_ms: f64,
    pub count: u64,
}

/// Fixed-capacity ring buffer of the most recent observations.
#[derive(Debug)]
struct Reservoir {
    buf: Vec<f64>,
    next: usize,
    cap: usize,
}

impl Reservoir {
    fn new(cap: usize) -> Reservoir {
        Reservoir { buf: Vec::new(), next: 0, cap: cap.max(1) }
    }

    fn push(&mut self, v: f64) {
        if self.buf.len() < self.cap {
            self.buf.push(v);
        } else {
            self.buf[self.next] = v;
        }
        self.next = (self.next + 1) % self.cap;
    }

    fn values(&self) -> &[f64] {
        &self.buf
    }
}

pub struct Metrics {
    inner: Mutex<Inner>,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::with_window(WINDOW_CAP)
    }
}

struct Inner {
    queue_ms: Reservoir,
    exec_ms: Reservoir,
    queue_hist: Hist,
    exec_hist: Hist,
    /// Exec seconds per op class (indices follow [`crate::obs::OP_CLASSES`]),
    /// drained from worker profiler rings after each batch.
    class_exec_s: [f64; N_CLASSES],
    completed: u64,
    errors: u64,
    batch_size_sum: u64,
    started: Option<Instant>,
}

#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    /// Requests answered successfully (monotonic).
    pub completed: usize,
    /// Requests answered with an execution error (monotonic).
    pub errors: usize,
    pub p50_exec_ms: f64,
    pub p95_exec_ms: f64,
    pub p99_exec_ms: f64,
    pub p50_queue_ms: f64,
    pub p95_queue_ms: f64,
    pub p99_queue_ms: f64,
    pub mean_batch: f64,
    pub throughput_rps: f64,
    /// Samples currently in the quantile window (≤ [`WINDOW_CAP`]).
    pub window: usize,
    /// Cumulative queue-wait histogram over [`HIST_BUCKETS_MS`].
    pub queue_hist: HistSnapshot,
    /// Cumulative exec-time histogram over [`HIST_BUCKETS_MS`].
    pub exec_hist: HistSnapshot,
    /// Exec seconds per op class ([`crate::obs::OP_CLASSES`] order).
    pub class_exec_s: [f64; N_CLASSES],
}

impl Metrics {
    pub fn with_window(cap: usize) -> Metrics {
        Metrics {
            inner: Mutex::new(Inner {
                queue_ms: Reservoir::new(cap),
                exec_ms: Reservoir::new(cap),
                queue_hist: Hist::default(),
                exec_hist: Hist::default(),
                class_exec_s: [0.0; N_CLASSES],
                completed: 0,
                errors: 0,
                batch_size_sum: 0,
                started: None,
            }),
        }
    }

    pub fn observe(&self, queue_ms: f64, exec_ms: f64, batch: usize) {
        let mut m = self.inner.lock().unwrap();
        m.started.get_or_insert_with(Instant::now);
        m.queue_ms.push(queue_ms);
        m.exec_ms.push(exec_ms);
        m.queue_hist.observe(queue_ms);
        m.exec_hist.observe(exec_ms);
        m.completed += 1;
        m.batch_size_sum += batch as u64;
    }

    /// Accumulate per-op-class exec seconds drained from a worker's
    /// profiler rings ([`crate::obs::InstrProfiler::drain_class_totals`]).
    pub fn observe_class_seconds(&self, cls: &[f64; N_CLASSES]) {
        let mut m = self.inner.lock().unwrap();
        for (acc, v) in m.class_exec_s.iter_mut().zip(cls) {
            *acc += v;
        }
    }

    /// Record `n` requests answered with an execution error.
    pub fn observe_errors(&self, n: usize) {
        let mut m = self.inner.lock().unwrap();
        m.started.get_or_insert_with(Instant::now);
        m.errors += n as u64;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.lock().unwrap();
        if m.completed == 0 && m.errors == 0 {
            return MetricsSnapshot::default();
        }
        let elapsed = m.started.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
        MetricsSnapshot {
            completed: m.completed as usize,
            errors: m.errors as usize,
            p50_exec_ms: percentile(m.exec_ms.values(), 0.50),
            p95_exec_ms: percentile(m.exec_ms.values(), 0.95),
            p99_exec_ms: percentile(m.exec_ms.values(), 0.99),
            p50_queue_ms: percentile(m.queue_ms.values(), 0.50),
            p95_queue_ms: percentile(m.queue_ms.values(), 0.95),
            p99_queue_ms: percentile(m.queue_ms.values(), 0.99),
            mean_batch: if m.completed > 0 {
                m.batch_size_sum as f64 / m.completed as f64
            } else {
                0.0
            },
            throughput_rps: if elapsed > 0.0 { m.completed as f64 / elapsed } else { 0.0 },
            window: m.exec_ms.values().len(),
            queue_hist: m.queue_hist.snapshot(),
            exec_hist: m.exec_hist.snapshot(),
            class_exec_s: m.class_exec_s,
        }
    }
}

pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((v.len() as f64 - 1.0) * q).round() as usize;
    v[idx.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (0..=100).map(|v| v as f64).collect();
        assert_eq!(percentile(&xs, 0.5), 50.0);
        assert_eq!(percentile(&xs, 0.95), 95.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn snapshot_aggregates() {
        let m = Metrics::default();
        for i in 0..10 {
            m.observe(1.0, 2.0 + i as f64, 2);
        }
        let s = m.snapshot();
        assert_eq!(s.completed, 10);
        assert_eq!(s.errors, 0);
        assert_eq!(s.mean_batch, 2.0);
        assert!(s.p95_exec_ms >= s.p50_exec_ms);
        assert!(s.p99_exec_ms >= s.p95_exec_ms);
    }

    #[test]
    fn long_run_memory_is_bounded_but_counters_exact() {
        let m = Metrics::with_window(64);
        for i in 0..10_000 {
            m.observe(0.5, i as f64, 1);
        }
        m.observe_errors(3);
        let s = m.snapshot();
        assert_eq!(s.completed, 10_000);
        assert_eq!(s.errors, 3);
        assert_eq!(s.window, 64);
        // quantiles reflect the recent window, not ancient history
        assert!(s.p50_exec_ms >= (10_000 - 64) as f64);
        assert!(s.p99_exec_ms >= s.p50_exec_ms);
        {
            let inner = m.inner.lock().unwrap();
            assert!(inner.exec_ms.values().len() <= 64);
            assert!(inner.queue_ms.values().len() <= 64);
        }
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_count_overflow() {
        let m = Metrics::default();
        // 0.05 -> first bucket (le=0.1); 3.0 -> le=5; 2000.0 -> +Inf only
        m.observe(0.05, 3.0, 1);
        m.observe(0.05, 2000.0, 1);
        let s = m.snapshot();
        assert_eq!(s.queue_hist.cumulative[0], 2);
        assert_eq!(*s.queue_hist.cumulative.last().unwrap(), 2);
        assert_eq!(s.queue_hist.count, 2);
        // exec: 3.0 lands at the first bound >= 3.0 (5.0, index 5) and
        // stays in every wider bucket; 2000.0 only raises the +Inf count
        assert_eq!(s.exec_hist.cumulative[4], 0); // le=2.5
        assert_eq!(s.exec_hist.cumulative[5], 1); // le=5
        assert_eq!(*s.exec_hist.cumulative.last().unwrap(), 1); // le=1000
        assert_eq!(s.exec_hist.count, 2);
        assert!((s.exec_hist.sum_ms - 2003.0).abs() < 1e-9);
    }

    #[test]
    fn class_seconds_accumulate() {
        let m = Metrics::default();
        let mut cls = [0.0; N_CLASSES];
        cls[0] = 0.25;
        cls[3] = 0.5;
        m.observe_class_seconds(&cls);
        m.observe_class_seconds(&cls);
        m.observe(0.1, 1.0, 1); // snapshot only renders after activity
        let s = m.snapshot();
        assert!((s.class_exec_s[0] - 0.5).abs() < 1e-12);
        assert!((s.class_exec_s[3] - 1.0).abs() < 1e-12);
        assert_eq!(s.class_exec_s[1], 0.0);
    }

    #[test]
    fn reservoir_overwrites_oldest() {
        let mut r = Reservoir::new(4);
        for i in 0..6 {
            r.push(i as f64);
        }
        let mut vals = r.values().to_vec();
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(vals, vec![2.0, 3.0, 4.0, 5.0]);
    }
}

//! Serving metrics: queue/exec latency quantiles, batch sizes, throughput.

use std::sync::Mutex;
use std::time::Instant;

#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    queue_ms: Vec<f64>,
    exec_ms: Vec<f64>,
    batch_sizes: Vec<usize>,
    started: Option<Instant>,
}

#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    pub completed: usize,
    pub p50_exec_ms: f64,
    pub p95_exec_ms: f64,
    pub p50_queue_ms: f64,
    pub p95_queue_ms: f64,
    pub mean_batch: f64,
    pub throughput_rps: f64,
}

impl Metrics {
    pub fn observe(&self, queue_ms: f64, exec_ms: f64, batch: usize) {
        let mut m = self.inner.lock().unwrap();
        m.started.get_or_insert_with(Instant::now);
        m.queue_ms.push(queue_ms);
        m.exec_ms.push(exec_ms);
        m.batch_sizes.push(batch);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.lock().unwrap();
        let completed = m.exec_ms.len();
        if completed == 0 {
            return MetricsSnapshot::default();
        }
        let elapsed = m.started.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
        MetricsSnapshot {
            completed,
            p50_exec_ms: percentile(&m.exec_ms, 0.50),
            p95_exec_ms: percentile(&m.exec_ms, 0.95),
            p50_queue_ms: percentile(&m.queue_ms, 0.50),
            p95_queue_ms: percentile(&m.queue_ms, 0.95),
            mean_batch: m.batch_sizes.iter().sum::<usize>() as f64 / completed as f64,
            throughput_rps: if elapsed > 0.0 { completed as f64 / elapsed } else { 0.0 },
        }
    }
}

pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((v.len() as f64 - 1.0) * q).round() as usize;
    v[idx.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (0..=100).map(|v| v as f64).collect();
        assert_eq!(percentile(&xs, 0.5), 50.0);
        assert_eq!(percentile(&xs, 0.95), 95.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn snapshot_aggregates() {
        let m = Metrics::default();
        for i in 0..10 {
            m.observe(1.0, 2.0 + i as f64, 2);
        }
        let s = m.snapshot();
        assert_eq!(s.completed, 10);
        assert_eq!(s.mean_batch, 2.0);
        assert!(s.p95_exec_ms >= s.p50_exec_ms);
    }
}
